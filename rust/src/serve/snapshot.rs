//! Immutable model snapshots — the unit the serving layer swaps.
//!
//! A [`ModelSnapshot`] captures everything needed to answer a predict
//! request: the weight tables, the tree wiring, and the routing
//! (sharder) identity, plus the bookkeeping the staleness metrics need
//! (publish version and training-stream position). Snapshots are
//! *immutable by construction*: the publisher builds a fresh one and
//! swaps the `Arc`, so readers can never observe a half-updated model
//! (the delayed-read regime of *Slow Learners are Fast* — readers see
//! slightly stale weights, never torn ones).

use crate::linalg::{sparse_dot, SparseFeat};
use crate::sharding::feature::FeatureSharder;
use crate::topology::NodeGraph;

/// Bounds-checked dot for *request* features: unlike the training hot
/// path, the serving path consumes untrusted client input, so an
/// out-of-range index must not hit `sparse_dot`'s unchecked access —
/// it simply contributes nothing (an unknown slot has no weight).
#[inline]
fn request_dot(w: &[f32], x: &[SparseFeat]) -> f64 {
    x.iter()
        .map(|&(i, v)| {
            w.get(i as usize).copied().unwrap_or(0.0) as f64 * v as f64
        })
        .sum()
}

/// The predictor inside a snapshot.
#[derive(Clone, Debug)]
pub enum SnapshotModel {
    /// A single flat weight table (plain [`crate::learner::sgd::Sgd`] or
    /// the centralized Minibatch/CG/SGD rules).
    Central { w: Vec<f32> },
    /// A feature-sharded node tree (the §0.5.2 architectures).
    Tree {
        graph: NodeGraph,
        sharder: FeatureSharder,
        /// Per-node weight tables, indexed by node id (leaves first).
        weights: Vec<Vec<f32>>,
        clip01: bool,
        bias: bool,
    },
}

/// An immutable, atomically-swappable model version.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Publish sequence number (assigned by the publisher; 0 when loaded
    /// straight from a checkpoint).
    pub version: u64,
    /// Training-stream position (instances learned) when this snapshot
    /// was taken — the baseline for instances-behind staleness.
    pub trained_instances: u64,
    /// Digest of the originating configuration (see
    /// [`crate::serve::checkpoint`]); lets a server refuse snapshots
    /// from a differently-configured trainer.
    pub config_digest: u64,
    pub model: SnapshotModel,
}

/// Reusable buffers for the allocation-free serving hot path.
#[derive(Clone, Debug, Default)]
pub struct PredictScratch {
    preds: Vec<f64>,
    leaf_bufs: Vec<Vec<SparseFeat>>,
    x: Vec<SparseFeat>,
}

impl ModelSnapshot {
    pub fn central(w: Vec<f32>, trained_instances: u64, config_digest: u64) -> Self {
        ModelSnapshot {
            version: 0,
            trained_instances,
            config_digest,
            model: SnapshotModel::Central { w },
        }
    }

    /// Hashed feature-space size this snapshot predicts over (the
    /// weight-table length of the flat model / every leaf).
    pub fn dim(&self) -> usize {
        match &self.model {
            SnapshotModel::Central { w } => w.len(),
            SnapshotModel::Tree { weights, graph, .. } => {
                weights.get(..graph.leaves).map_or(0, |ls| {
                    ls.first().map_or(0, Vec::len)
                })
            }
        }
    }

    /// Total parameters across all tables (reporting).
    pub fn num_params(&self) -> usize {
        match &self.model {
            SnapshotModel::Central { w } => w.len(),
            SnapshotModel::Tree { weights, .. } => {
                weights.iter().map(Vec::len).sum()
            }
        }
    }

    /// Predict with caller-owned scratch (the serving hot path: no
    /// allocation after the first call per thread).
    pub fn predict_with(&self, x: &[SparseFeat], s: &mut PredictScratch) -> f64 {
        match &self.model {
            SnapshotModel::Central { w } => request_dot(w, x),
            SnapshotModel::Tree { graph, sharder, weights, clip01, bias } => {
                let n = graph.num_nodes();
                s.preds.clear();
                s.preds.resize(n, 0.0);
                if s.leaf_bufs.len() != graph.leaves {
                    s.leaf_bufs = vec![Vec::new(); graph.leaves];
                }
                sharder.split_features_into(x, &mut s.leaf_bufs);
                for leaf in 0..graph.leaves {
                    s.preds[leaf] =
                        request_dot(&weights[leaf], &s.leaf_bufs[leaf]);
                }
                for id in graph.leaves..n {
                    let kids = &graph.children[id];
                    s.x.clear();
                    for (rank, &c) in kids.iter().enumerate() {
                        let p = if *clip01 {
                            s.preds[c].clamp(0.0, 1.0)
                        } else {
                            s.preds[c]
                        };
                        s.x.push((rank as u32, p as f32));
                    }
                    if *bias {
                        s.x.push((kids.len() as u32, 1.0));
                    }
                    s.preds[id] = sparse_dot(&weights[id], &s.x);
                }
                s.preds[graph.root]
            }
        }
    }

    /// Convenience predict (allocates scratch; use
    /// [`Self::predict_with`] on the hot path).
    pub fn predict(&self, x: &[SparseFeat]) -> f64 {
        let mut s = PredictScratch::default();
        self.predict_with(x, &mut s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn central_predicts_dot() {
        let snap = ModelSnapshot::central(vec![1.0, 2.0, 0.0, -1.0], 10, 7);
        assert_eq!(snap.predict(&[(0, 1.0), (1, 0.5)]), 2.0);
        assert_eq!(snap.dim(), 4);
        assert_eq!(snap.num_params(), 4);
    }

    #[test]
    fn tree_predicts_through_master() {
        // 2 leaves + master; master weights [1, 1, 0] (children + bias)
        let graph = Topology::TwoLayer { shards: 2 }.build();
        let sharder = FeatureSharder::hash(2);
        // each leaf has a 4-slot table of ones: leaf pred = sum of its
        // shard's feature values
        let weights = vec![vec![1.0f32; 4], vec![1.0f32; 4], vec![1.0, 1.0, 0.0]];
        let snap = ModelSnapshot {
            version: 1,
            trained_instances: 5,
            config_digest: 0,
            model: SnapshotModel::Tree {
                graph,
                sharder,
                weights,
                clip01: false,
                bias: true,
            },
        };
        // whichever shard each feature routes to, the unclipped master
        // with unit child weights sums the leaf predictions
        let x = [(0u32, 0.5f32), (1, 0.25), (2, 0.125)];
        let y = snap.predict(&x);
        assert!((y - 0.875).abs() < 1e-9, "{y}");
        assert_eq!(snap.dim(), 4);
        assert_eq!(snap.num_params(), 11);
    }

    #[test]
    fn out_of_range_request_features_are_ignored() {
        // serving consumes untrusted input: an index beyond the weight
        // table must contribute nothing, not read out of bounds
        let snap = ModelSnapshot::central(vec![1.0, 2.0], 0, 0);
        assert_eq!(snap.predict(&[(0, 1.0), (u32::MAX, 5.0)]), 1.0);
        let graph = Topology::TwoLayer { shards: 2 }.build();
        let tree = ModelSnapshot {
            version: 0,
            trained_instances: 0,
            config_digest: 0,
            model: SnapshotModel::Tree {
                graph,
                sharder: FeatureSharder::hash(2),
                weights: vec![vec![1.0; 4], vec![1.0; 4], vec![1.0, 1.0, 0.0]],
                clip01: false,
                bias: true,
            },
        };
        let with_oob = tree.predict(&[(0, 0.5), (1_000_000, 9.0)]);
        let without = tree.predict(&[(0, 0.5)]);
        assert_eq!(with_oob, without);
    }

    #[test]
    fn predict_with_reuses_scratch_consistently() {
        let graph = Topology::BinaryTree { leaves: 4 }.build();
        let sharder = FeatureSharder::hash(4);
        let mut weights: Vec<Vec<f32>> = (0..graph.num_nodes())
            .map(|id| {
                if graph.is_leaf(id) {
                    (0..8).map(|i| (i as f32) * 0.1).collect()
                } else {
                    vec![0.5; graph.children[id].len() + 1]
                }
            })
            .collect();
        weights[0][0] = -0.3;
        let snap = ModelSnapshot {
            version: 0,
            trained_instances: 0,
            config_digest: 0,
            model: SnapshotModel::Tree {
                graph,
                sharder,
                weights,
                clip01: true,
                bias: true,
            },
        };
        let mut scratch = PredictScratch::default();
        let x1 = [(0u32, 1.0f32), (5, -2.0)];
        let x2 = [(3u32, 0.5f32)];
        let a1 = snap.predict_with(&x1, &mut scratch);
        let b1 = snap.predict_with(&x2, &mut scratch);
        // same inputs again with dirty scratch must agree with fresh
        assert_eq!(a1, snap.predict(&x1));
        assert_eq!(b1, snap.predict(&x2));
        assert_eq!(a1, snap.predict_with(&x1, &mut scratch));
    }
}
