//! Immutable model snapshots — the unit the serving layer swaps.
//!
//! A [`ModelSnapshot`] captures everything needed to answer a predict
//! request: an immutable predictor plus the bookkeeping the staleness
//! metrics need (publish version and training-stream position).
//! Snapshots are *immutable by construction*: the publisher builds a
//! fresh one and swaps the `Arc`, so readers can never observe a
//! half-updated model (the delayed-read regime of *Slow Learners are
//! Fast* — readers see slightly stale weights, never torn ones).
//!
//! The predictor inside a snapshot is a [`SnapshotPredict`] trait
//! object, not an enum: the serving path ([`crate::serve::server`]) and
//! every caller of [`ModelSnapshot::predict`] dispatch through the
//! trait, so adding an architecture means adding an implementation —
//! the only place that still branches on model kind is the checkpoint
//! codec that constructs predictors from disk.

use std::sync::Arc;

use crate::linalg::{sparse_dot, SparseFeat};
use crate::sharding::ShardPlan;
use crate::topology::NodeGraph;

/// Bounds-checked dot for *request* features: unlike the training hot
/// path, the serving path consumes untrusted client input, so an
/// out-of-range index must not hit `sparse_dot`'s unchecked access —
/// it simply contributes nothing (an unknown slot has no weight).
/// Bit-identical to `sparse_dot` for in-range input (same accumulation
/// order), which the snapshot-vs-live bit-parity tests rely on.
#[inline]
pub(crate) fn request_dot(w: &[f32], x: &[SparseFeat]) -> f64 {
    x.iter()
        .map(|&(i, v)| {
            w.get(i as usize).copied().unwrap_or(0.0) as f64 * v as f64
        })
        .sum()
}

/// Reusable buffers for the allocation-free predict hot path (shared by
/// snapshot serving and [`crate::coordinator::Coordinator`] test-set
/// prediction).
#[derive(Clone, Debug, Default)]
pub struct PredictScratch {
    pub(crate) preds: Vec<f64>,
    pub(crate) leaf_bufs: Vec<Vec<SparseFeat>>,
    pub(crate) x: Vec<SparseFeat>,
}

/// The one tree-combine walk: split features to the leaves, score every
/// node bottom-up via `node_score`, feeding internal nodes the
/// (child-rank, optionally-clipped child prediction) rows plus the bias
/// feature. Both [`TreePredictor`] (serving) and the live
/// [`crate::coordinator::Coordinator`] predict through this
/// implementation, so combine semantics cannot drift between the
/// training side and the serving side.
pub(crate) fn tree_predict_with(
    graph: &NodeGraph,
    plan: &ShardPlan,
    clip01: bool,
    bias: bool,
    x: &[SparseFeat],
    s: &mut PredictScratch,
    mut node_score: impl FnMut(usize, &[SparseFeat]) -> f64,
) -> f64 {
    let n = graph.num_nodes();
    s.preds.clear();
    s.preds.resize(n, 0.0);
    if s.leaf_bufs.len() != graph.leaves {
        s.leaf_bufs = vec![Vec::new(); graph.leaves];
    }
    plan.split_features_into(x, &mut s.leaf_bufs);
    for leaf in 0..graph.leaves {
        s.preds[leaf] = node_score(leaf, &s.leaf_bufs[leaf]);
    }
    for id in graph.leaves..n {
        let kids = &graph.children[id];
        s.x.clear();
        for (rank, &c) in kids.iter().enumerate() {
            let p = if clip01 {
                s.preds[c].clamp(0.0, 1.0)
            } else {
                s.preds[c]
            };
            s.x.push((rank as u32, p as f32));
        }
        if bias {
            s.x.push((kids.len() as u32, 1.0));
        }
        s.preds[id] = node_score(id, &s.x);
    }
    s.preds[graph.root]
}

/// The predictor inside a [`ModelSnapshot`]: one immutable, thread-safe
/// scoring function. Implementations are architecture-specific
/// ([`CentralPredictor`], [`TreePredictor`]); everything downstream of
/// the checkpoint codec dispatches through this trait.
pub trait SnapshotPredict: Send + Sync + std::fmt::Debug {
    /// Score one request with caller-owned scratch (the serving hot
    /// path: no allocation after the first call per thread). Request
    /// features are untrusted: out-of-range indices contribute nothing.
    fn predict_with(&self, x: &[SparseFeat], s: &mut PredictScratch) -> f64;

    /// Hashed feature-space size this predictor scores over.
    fn dim(&self) -> usize;

    /// Total parameters across all tables (reporting).
    fn num_params(&self) -> usize;

    /// The flat weight table, if this predictor is a single table
    /// (reporting and tests; tree predictors return `None`).
    fn weights_flat(&self) -> Option<&[f32]> {
        None
    }
}

/// A single flat weight table (plain [`crate::learner::sgd::Sgd`] or
/// the centralized Minibatch/CG/SGD rules).
#[derive(Clone, Debug)]
pub struct CentralPredictor {
    /// Flat weight vector, cache-line aligned for the serving dot.
    pub w: crate::simd::AlignedTable,
}

impl SnapshotPredict for CentralPredictor {
    #[inline]
    fn predict_with(&self, x: &[SparseFeat], _s: &mut PredictScratch) -> f64 {
        request_dot(&self.w, x)
    }

    fn dim(&self) -> usize {
        self.w.len()
    }

    fn num_params(&self) -> usize {
        self.w.len()
    }

    fn weights_flat(&self) -> Option<&[f32]> {
        Some(self.w.as_slice())
    }
}

/// A feature-sharded node tree (the §0.5.2 architectures).
#[derive(Clone, Debug)]
pub struct TreePredictor {
    /// Node graph the predictor mirrors.
    pub graph: NodeGraph,
    /// The routing the leaves were trained under — the same
    /// [`ShardPlan`] the coordinator, pipeline, and codec hold.
    pub plan: ShardPlan,
    /// Per-node weight tables, indexed by node id (leaves first).
    pub weights: Vec<Vec<f32>>,
    /// Clip the master output to `[0, 1]`.
    pub clip01: bool,
    /// Whether a bias slot is present.
    pub bias: bool,
}

impl SnapshotPredict for TreePredictor {
    fn predict_with(&self, x: &[SparseFeat], s: &mut PredictScratch) -> f64 {
        tree_predict_with(
            &self.graph,
            &self.plan,
            self.clip01,
            self.bias,
            x,
            s,
            // leaves consume untrusted request features (bounds-checked
            // dot); internal rows are constructed in-walk, so the
            // unchecked dot is safe there
            |id, row| {
                if self.graph.is_leaf(id) {
                    request_dot(&self.weights[id], row)
                } else {
                    sparse_dot(&self.weights[id], row)
                }
            },
        )
    }

    fn dim(&self) -> usize {
        self.weights
            .get(..self.graph.leaves)
            .map_or(0, |ls| ls.first().map_or(0, Vec::len))
    }

    fn num_params(&self) -> usize {
        self.weights.iter().map(Vec::len).sum()
    }
}

/// An immutable, atomically-swappable model version.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Publish sequence number (assigned by the publisher; 0 when loaded
    /// straight from a checkpoint).
    pub version: u64,
    /// Training-stream position (instances learned) when this snapshot
    /// was taken — the baseline for instances-behind staleness.
    pub trained_instances: u64,
    /// Digest of the originating configuration (see
    /// [`crate::serve::checkpoint`]); lets a server refuse snapshots
    /// from a differently-configured trainer.
    pub config_digest: u64,
    predictor: Arc<dyn SnapshotPredict>,
}

impl ModelSnapshot {
    /// Wrap an arbitrary predictor.
    pub fn from_predictor(
        predictor: Arc<dyn SnapshotPredict>,
        trained_instances: u64,
        config_digest: u64,
    ) -> Self {
        ModelSnapshot { version: 0, trained_instances, config_digest, predictor }
    }

    /// A flat-table snapshot.
    pub fn central(w: Vec<f32>, trained_instances: u64, config_digest: u64) -> Self {
        Self::from_predictor(
            Arc::new(CentralPredictor { w: crate::simd::AlignedTable::from_vec(w) }),
            trained_instances,
            config_digest,
        )
    }

    /// A feature-sharded tree snapshot.
    pub fn tree(
        tree: TreePredictor,
        trained_instances: u64,
        config_digest: u64,
    ) -> Self {
        Self::from_predictor(Arc::new(tree), trained_instances, config_digest)
    }

    /// The predictor itself (trait object).
    pub fn predictor(&self) -> &Arc<dyn SnapshotPredict> {
        &self.predictor
    }

    /// Hashed feature-space size this snapshot predicts over (the
    /// weight-table length of the flat model / every leaf).
    pub fn dim(&self) -> usize {
        self.predictor.dim()
    }

    /// Total parameters across all tables (reporting).
    pub fn num_params(&self) -> usize {
        self.predictor.num_params()
    }

    /// The flat weight table, when the snapshot holds a single-table
    /// predictor (reporting and tests).
    pub fn weights_flat(&self) -> Option<&[f32]> {
        self.predictor.weights_flat()
    }

    /// Predict with caller-owned scratch (the serving hot path: no
    /// allocation after the first call per thread).
    #[inline]
    pub fn predict_with(&self, x: &[SparseFeat], s: &mut PredictScratch) -> f64 {
        self.predictor.predict_with(x, s)
    }

    /// Convenience predict (allocates scratch; use
    /// [`Self::predict_with`] on the hot path).
    pub fn predict(&self, x: &[SparseFeat]) -> f64 {
        let mut s = PredictScratch::default();
        self.predict_with(x, &mut s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn central_predicts_dot() {
        let snap = ModelSnapshot::central(vec![1.0, 2.0, 0.0, -1.0], 10, 7);
        assert_eq!(snap.predict(&[(0, 1.0), (1, 0.5)]), 2.0);
        assert_eq!(snap.dim(), 4);
        assert_eq!(snap.num_params(), 4);
        assert_eq!(snap.weights_flat(), Some(&[1.0f32, 2.0, 0.0, -1.0][..]));
    }

    #[test]
    fn tree_predicts_through_master() {
        // 2 leaves + master; master weights [1, 1, 0] (children + bias)
        let graph = Topology::TwoLayer { shards: 2 }.build();
        let plan = ShardPlan::hash(2, 4);
        // each leaf has a 4-slot table of ones: leaf pred = sum of its
        // shard's feature values
        let weights = vec![vec![1.0f32; 4], vec![1.0f32; 4], vec![1.0, 1.0, 0.0]];
        let snap = ModelSnapshot::tree(
            TreePredictor { graph, plan, weights, clip01: false, bias: true },
            5,
            0,
        );
        // whichever shard each feature routes to, the unclipped master
        // with unit child weights sums the leaf predictions
        let x = [(0u32, 0.5f32), (1, 0.25), (2, 0.125)];
        let y = snap.predict(&x);
        assert!((y - 0.875).abs() < 1e-9, "{y}");
        assert_eq!(snap.dim(), 4);
        assert_eq!(snap.num_params(), 11);
        assert_eq!(snap.weights_flat(), None);
    }

    #[test]
    fn out_of_range_request_features_are_ignored() {
        // serving consumes untrusted input: an index beyond the weight
        // table must contribute nothing, not read out of bounds
        let snap = ModelSnapshot::central(vec![1.0, 2.0], 0, 0);
        assert_eq!(snap.predict(&[(0, 1.0), (u32::MAX, 5.0)]), 1.0);
        let graph = Topology::TwoLayer { shards: 2 }.build();
        let tree = ModelSnapshot::tree(
            TreePredictor {
                graph,
                plan: ShardPlan::hash(2, 4),
                weights: vec![vec![1.0; 4], vec![1.0; 4], vec![1.0, 1.0, 0.0]],
                clip01: false,
                bias: true,
            },
            0,
            0,
        );
        let with_oob = tree.predict(&[(0, 0.5), (1_000_000, 9.0)]);
        let without = tree.predict(&[(0, 0.5)]);
        assert_eq!(with_oob, without);
    }

    #[test]
    fn predict_with_reuses_scratch_consistently() {
        let graph = Topology::BinaryTree { leaves: 4 }.build();
        let plan = ShardPlan::hash(4, 8);
        let mut weights: Vec<Vec<f32>> = (0..graph.num_nodes())
            .map(|id| {
                if graph.is_leaf(id) {
                    (0..8).map(|i| (i as f32) * 0.1).collect()
                } else {
                    vec![0.5; graph.children[id].len() + 1]
                }
            })
            .collect();
        weights[0][0] = -0.3;
        let snap = ModelSnapshot::tree(
            TreePredictor { graph, plan, weights, clip01: true, bias: true },
            0,
            0,
        );
        let mut scratch = PredictScratch::default();
        let x1 = [(0u32, 1.0f32), (5, -2.0)];
        let x2 = [(3u32, 0.5f32)];
        let a1 = snap.predict_with(&x1, &mut scratch);
        let b1 = snap.predict_with(&x2, &mut scratch);
        // same inputs again with dirty scratch must agree with fresh
        assert_eq!(a1, snap.predict(&x1));
        assert_eq!(b1, snap.predict(&x2));
        assert_eq!(a1, snap.predict_with(&x1, &mut scratch));
    }
}
