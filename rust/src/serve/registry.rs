//! The model registry: N named models behind one prediction server.
//!
//! A [`ModelRegistry`] maps model names to [`SnapshotCell`]s, so one
//! [`crate::serve::server::PredictionServer`] can host several
//! architectures — a sharded tree next to a centralized SGD table next
//! to a plain checkpointed learner — each independently live-updatable
//! through its own cell, each with its own staleness/latency/QPS
//! metrics.
//!
//! The registry is read-mostly: serving workers cache a
//! [`crate::serve::SnapshotReader`] per model and only re-resolve names
//! when the registry `version` changes (one atomic load per request in
//! steady state, exactly like the snapshot fast path). `insert` and
//! `remove` bump the version, which invalidates every worker cache on
//! its next request.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::error::LockExt;
use crate::serve::publisher::{SnapshotCell, SnapshotReader};
use crate::serve::snapshot::PredictScratch;

/// Named [`SnapshotCell`]s behind one server.
pub struct ModelRegistry {
    /// Bumped on every insert/remove; serving workers re-resolve their
    /// cached readers when it changes.
    version: AtomicU64,
    /// Whole-`Arc` values in, whole-`Arc` values out — every critical
    /// section leaves the map valid, so lock poisoning is recovered
    /// (`recover_poisoned`) rather than cascading a peer's panic.
    models: RwLock<HashMap<String, Arc<SnapshotCell>>>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry {
            version: AtomicU64::new(0),
            models: RwLock::new(HashMap::new()),
        })
    }

    /// A registry holding one named model.
    pub fn with_model(
        name: impl Into<String>,
        cell: Arc<SnapshotCell>,
    ) -> Arc<ModelRegistry> {
        let reg = ModelRegistry::new();
        reg.insert(name, cell);
        reg
    }

    /// Register (or replace) a model; returns the previous cell under
    /// that name, if any.
    pub fn insert(
        &self,
        name: impl Into<String>,
        cell: Arc<SnapshotCell>,
    ) -> Option<Arc<SnapshotCell>> {
        let prev = self
            .models
            .write()
            .recover_poisoned()
            .insert(name.into(), cell);
        self.version.fetch_add(1, Ordering::Release);
        prev
    }

    /// Deregister a model; in-flight requests already resolved keep
    /// their snapshot, new requests get an unknown-model error.
    pub fn remove(&self, name: &str) -> Option<Arc<SnapshotCell>> {
        let prev = self.models.write().recover_poisoned().remove(name);
        if prev.is_some() {
            self.version.fetch_add(1, Ordering::Release);
        }
        prev
    }

    /// Resolve a model name to its cell.
    pub fn get(&self, name: &str) -> Option<Arc<SnapshotCell>> {
        self.models.read().recover_poisoned().get(name).cloned()
    }

    /// Registered model names, sorted (stable reporting order).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .models
            .read()
            .recover_poisoned()
            .keys()
            .cloned()
            .collect();
        names.sort();
        names
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.read().recover_poisoned().len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current registry version (bumped on insert/remove).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }
}

/// Per-thread cache of resolved models: a [`SnapshotReader`] plus
/// private predict scratch per name, invalidated wholesale when the
/// registry version changes (so renames and replacements take effect
/// on the next request). Both the in-process
/// [`crate::serve::server::PredictionServer`] workers and the
/// [`crate::wire`] connection handlers resolve through this, so the
/// two serving paths share one fast path and cannot drift: one atomic
/// load per steady-state request, and the name string is cloned only
/// the first time this thread sees a model.
pub struct ModelCache {
    models: HashMap<String, (SnapshotReader, PredictScratch)>,
    version: u64,
}

impl ModelCache {
    /// A cache over `registry`'s current contents.
    pub fn new(registry: &ModelRegistry) -> ModelCache {
        ModelCache { models: HashMap::new(), version: registry.version() }
    }

    /// Resolve a model name to its cached `(reader, scratch)` pair;
    /// `None` when the registry has no model under that name.
    pub fn resolve(
        &mut self,
        registry: &ModelRegistry,
        name: &str,
    ) -> Option<&mut (SnapshotReader, PredictScratch)> {
        let v = registry.version();
        if v != self.version {
            self.models.clear();
            self.version = v;
        }
        if !self.models.contains_key(name) {
            let cell = registry.get(name)?;
            self.models.insert(
                name.to_string(),
                (SnapshotReader::new(cell), PredictScratch::default()),
            );
        }
        self.models.get_mut(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::snapshot::ModelSnapshot;

    fn cell(val: f32) -> Arc<SnapshotCell> {
        SnapshotCell::new(ModelSnapshot::central(vec![val; 4], 0, 0))
    }

    #[test]
    fn insert_get_remove_bump_version() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.version(), 0);
        assert!(reg.insert("a", cell(1.0)).is_none());
        assert_eq!(reg.version(), 1);
        assert_eq!(reg.len(), 1);
        assert!(reg.get("a").is_some());
        assert!(reg.get("b").is_none());
        // replacing returns the old cell and bumps the version
        assert!(reg.insert("a", cell(2.0)).is_some());
        assert_eq!(reg.version(), 2);
        let got = reg.get("a").unwrap().load();
        assert_eq!(got.predict(&[(0, 1.0)]), 2.0);
        assert!(reg.remove("a").is_some());
        assert_eq!(reg.version(), 3);
        // removing a missing name is a no-op
        assert!(reg.remove("a").is_none());
        assert_eq!(reg.version(), 3);
    }

    #[test]
    fn names_sorted() {
        let reg = ModelRegistry::new();
        reg.insert("zeta", cell(0.0));
        reg.insert("alpha", cell(0.0));
        reg.insert("mid", cell(0.0));
        assert_eq!(reg.names(), vec!["alpha", "mid", "zeta"]);
    }

    #[test]
    fn with_model_seeds_one_entry() {
        let reg = ModelRegistry::with_model("m", cell(3.0));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m").unwrap().load().predict(&[(1, 2.0)]), 6.0);
    }

    #[test]
    fn model_cache_tracks_registry_changes() {
        let reg = ModelRegistry::with_model("a", cell(1.0));
        let mut cache = ModelCache::new(&reg);
        {
            let (reader, scratch) = cache.resolve(&reg, "a").unwrap();
            let snap = std::sync::Arc::clone(reader.current());
            assert_eq!(snap.predict_with(&[(0, 1.0)], scratch), 1.0);
        }
        assert!(cache.resolve(&reg, "ghost").is_none());
        // a replacement under the same name takes effect on the next
        // resolve (version bump invalidates the cached reader)
        reg.insert("a", cell(2.0));
        let (reader, scratch) = cache.resolve(&reg, "a").unwrap();
        let snap = std::sync::Arc::clone(reader.current());
        assert_eq!(snap.predict_with(&[(0, 1.0)], scratch), 2.0);
        // a removal stops resolving
        reg.remove("a");
        assert!(cache.resolve(&reg, "a").is_none());
    }
}
