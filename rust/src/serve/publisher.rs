//! Snapshot publication: the trainer-side half of train-while-serve.
//!
//! A [`SnapshotCell`] holds the live model behind an atomically
//! swappable `Arc<ModelSnapshot>`. The design is seqlock-shaped but
//! tear-free by construction: the publisher swaps a fully-built
//! immutable snapshot under a mutex and then bumps an atomic sequence
//! number; readers keep a thread-local cached `Arc` ([`SnapshotReader`])
//! and touch the mutex only when the sequence number says a newer
//! snapshot exists. The serving fast path is therefore one atomic load
//! per request — readers never contend with each other, and contend
//! with the publisher only once per publish, never per request.
//!
//! Staleness is first-class: the trainer bumps `latest_trained` every
//! instance, each snapshot records the stream position it was taken at,
//! and `staleness_of` reports how many instances behind the served
//! model is — the delay quantity bounded by the τ-analysis of *Slow
//! Learners are Fast* / *Online Learning under Delayed Feedback*.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::LockExt;
use crate::serve::snapshot::ModelSnapshot;

/// The swappable holder of the latest published model.
pub struct SnapshotCell {
    /// Publish count; also the `version` stamped on each snapshot.
    seq: AtomicU64,
    /// Training-stream position (instances learned so far) — advances
    /// between publishes, so staleness is measurable at any moment.
    latest_trained: AtomicU64,
    slot: Mutex<Arc<ModelSnapshot>>,
}

impl SnapshotCell {
    /// Wrap an initial snapshot (version forced to 0).
    pub fn new(mut initial: ModelSnapshot) -> Arc<SnapshotCell> {
        initial.version = 0;
        let trained = initial.trained_instances;
        Arc::new(SnapshotCell {
            seq: AtomicU64::new(0),
            latest_trained: AtomicU64::new(trained),
            slot: Mutex::new(Arc::new(initial)),
        })
    }

    /// Swap in a freshly built snapshot; returns its assigned version.
    pub fn publish(&self, mut snap: ModelSnapshot) -> u64 {
        // slot holds a whole Arc: swaps are atomic, recovery is sound
        let mut slot = self.slot.lock().recover_poisoned();
        // the publication edge is the Release store below
        // pol-lint: allow(L002, "read under slot mutex; Release store publishes")
        let version = self.seq.load(Ordering::Relaxed) + 1;
        snap.version = version;
        self.record_trained(snap.trained_instances);
        *slot = Arc::new(snap);
        // release-store after the slot is updated: a reader that sees
        // the new seq will find (at least) this snapshot in the slot
        self.seq.store(version, Ordering::Release);
        version
    }

    /// Latest snapshot (locks; serving threads should prefer
    /// [`SnapshotReader`], which only locks when the version changed).
    pub fn load(&self) -> Arc<ModelSnapshot> {
        // slot holds a whole Arc: swaps are atomic, recovery is sound
        Arc::clone(&self.slot.lock().recover_poisoned())
    }

    /// Number of publishes so far.
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    /// Trainer heartbeat: record the training-stream position (monotone).
    pub fn record_trained(&self, trained: u64) {
        self.latest_trained.fetch_max(trained, Ordering::AcqRel);
    }

    /// Training-stream position of the most advanced trainer heartbeat.
    pub fn latest_trained(&self) -> u64 {
        self.latest_trained.load(Ordering::Acquire)
    }

    /// Instances-behind staleness of a snapshot right now.
    pub fn staleness_of(&self, snap: &ModelSnapshot) -> u64 {
        self.latest_trained().saturating_sub(snap.trained_instances)
    }
}

/// Per-thread cached view of a [`SnapshotCell`]: the serving fast path.
pub struct SnapshotReader {
    cell: Arc<SnapshotCell>,
    cached_seq: u64,
    cached: Arc<ModelSnapshot>,
}

impl SnapshotReader {
    /// A reader over `cell`.
    pub fn new(cell: Arc<SnapshotCell>) -> Self {
        let cached = cell.load();
        let cached_seq = cached.version;
        SnapshotReader { cell, cached_seq, cached }
    }

    /// The latest snapshot — one atomic load when nothing changed, one
    /// mutex acquisition per publish otherwise. Never returns a torn
    /// model (snapshots are immutable) and never goes backwards.
    #[inline]
    pub fn current(&mut self) -> &Arc<ModelSnapshot> {
        let seq = self.cell.seq.load(Ordering::Acquire);
        if seq != self.cached_seq {
            let fresh = self.cell.load();
            // monotonicity: a racing publisher can only leave a *newer*
            // snapshot in the slot than the seq we read
            if fresh.version >= self.cached.version {
                self.cached = fresh;
            }
            self.cached_seq = seq.max(self.cached.version);
        }
        &self.cached
    }

    /// The shared cell this reader polls.
    pub fn cell(&self) -> &Arc<SnapshotCell> {
        &self.cell
    }
}

/// The coordinator-side hook: every `every` trained instances, build an
/// immutable snapshot and publish it while training keeps running.
pub struct SnapshotPublisher {
    cell: Arc<SnapshotCell>,
    /// Publish cadence K, in trained instances.
    pub every: u64,
    next_at: u64,
    published: u64,
}

impl SnapshotPublisher {
    /// A publisher refreshing `cell` every `every` updates.
    pub fn new(cell: Arc<SnapshotCell>, every: u64) -> Self {
        let every = every.max(1);
        let next_at = cell.latest_trained() + every;
        SnapshotPublisher { cell, every, next_at, published: 0 }
    }

    /// The shared cell this publisher writes.
    pub fn cell(&self) -> &Arc<SnapshotCell> {
        &self.cell
    }

    /// Number of snapshots published so far.
    pub fn published(&self) -> u64 {
        self.published
    }

    /// Trainer heartbeat after one more instance; returns whether the
    /// cadence says a fresh snapshot is due.
    #[inline]
    pub fn tick(&mut self, trained: u64) -> bool {
        self.cell.record_trained(trained);
        trained >= self.next_at
    }

    /// Publish a freshly built snapshot and re-arm the cadence.
    pub fn publish(&mut self, snap: ModelSnapshot) {
        let at = snap.trained_instances;
        self.cell.publish(snap);
        self.published += 1;
        self.next_at = at + self.every;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(trained: u64, val: f32) -> ModelSnapshot {
        ModelSnapshot::central(vec![val; 8], trained, 0)
    }

    #[test]
    fn publish_bumps_version_and_swaps() {
        let cell = SnapshotCell::new(snap(0, 0.0));
        assert_eq!(cell.seq(), 0);
        let v = cell.publish(snap(100, 1.0));
        assert_eq!(v, 1);
        assert_eq!(cell.seq(), 1);
        let s = cell.load();
        assert_eq!(s.version, 1);
        assert_eq!(s.trained_instances, 100);
    }

    #[test]
    fn staleness_tracks_heartbeat() {
        let cell = SnapshotCell::new(snap(0, 0.0));
        cell.publish(snap(100, 1.0));
        let s = cell.load();
        assert_eq!(cell.staleness_of(&s), 0);
        cell.record_trained(140);
        assert_eq!(cell.staleness_of(&s), 40);
        // heartbeats are monotone: an older report cannot move it back
        cell.record_trained(120);
        assert_eq!(cell.staleness_of(&s), 40);
    }

    #[test]
    fn reader_sees_updates_and_never_regresses() {
        let cell = SnapshotCell::new(snap(0, 0.0));
        let mut r = SnapshotReader::new(Arc::clone(&cell));
        assert_eq!(r.current().version, 0);
        cell.publish(snap(50, 1.0));
        cell.publish(snap(90, 2.0));
        let v = r.current().version;
        assert_eq!(v, 2);
        assert_eq!(r.current().version, 2);
    }

    #[test]
    fn publisher_cadence() {
        let cell = SnapshotCell::new(snap(0, 0.0));
        let mut p = SnapshotPublisher::new(Arc::clone(&cell), 10);
        let mut published = Vec::new();
        for t in 1..=35u64 {
            if p.tick(t) {
                p.publish(snap(t, t as f32));
                published.push(t);
            }
        }
        assert_eq!(published, vec![10, 20, 30]);
        assert_eq!(p.published(), 3);
        assert_eq!(cell.load().trained_instances, 30);
        assert_eq!(cell.latest_trained(), 35);
    }
}
