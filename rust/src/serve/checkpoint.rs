//! `.polz` — the versioned, self-describing checkpoint format.
//!
//! Any trained topology round-trips to disk and warm-starts: a plain
//! [`Sgd`], a centralized (Minibatch/CG/SGD) coordinator, or a full
//! feature-sharded node tree. The format is self-describing (the
//! canonical config text rides along) and tamper-evident (whole-payload
//! FNV-1a checksum + config digest), so truncated or corrupted bytes
//! come back as [`io::Error`]s — never a panic, never a silently wrong
//! model.
//!
//! This module is deliberately the **only** place in the crate that
//! branches on model kind: everything above it — the builder, the CLI,
//! the prediction server — works through [`crate::model::Model`] and
//! [`crate::serve::snapshot::SnapshotPredict`] trait dispatch, and the
//! codec's job is exactly to turn bytes into those trait objects
//! ([`read_model`]) and back ([`crate::model::Model::write`]).
//!
//! Layout (all integers little-endian):
//! ```text
//! magic "POLZ" | u32 format version | u8 payload encoding
//! shard plan (u8 kind: 0 hash / 1 range / 2 none, u32 shards, u64 dim)
//! u64 config digest | u64 payload checksum (FNV-1a over
//! encoding byte ‖ plan bytes ‖ payload) | u64 payload length
//! payload:
//!   u8 kind (0 = sgd, 1 = central coordinator, 2 = tree coordinator)
//!   u32 config-text length | config text (canonical `key = value`)
//!   u64 dim | u64 routing salt (sharder signature; 0 for sgd/central)
//!   u64 trained instances
//!   u32 table count
//!   per table (encoding 0, raw):
//!     u64 step clock | u64 length | length × f32 weights
//!   per table (encoding 1, zero-run sparse):
//!     u64 step clock | u64 length | u32 run count
//!     per run: u32 start | u32 count | count × f32 weights
//! ```
//! Online-learned weight tables over hashed feature spaces are mostly
//! zeros (only touched slots ever move), so encoding 1 stores just the
//! non-zero stretches; the writer picks whichever encoding is smaller
//! for the whole file, and zeros inside a run are kept verbatim so the
//! round-trip stays bit-identical (a `-0.0` weight has non-zero bits
//! and is always stored explicitly). Format version 3 serializes the
//! [`ShardPlan`] into the header (kind, shard count, dim), so tools —
//! `pol checkpoint`, `pol reshard` — can read the routing without
//! parsing the config text; the payload layout is unchanged from v2.
//! Version 1 files (no encoding byte, raw tables, checksum over the
//! payload alone) and version 2 files (no header plan) are still
//! readable.
//!
//! The config digest is FNV-1a over (config text ‖ dim ‖ salt), where
//! the salt is the plan's signature — the serving process verifies it
//! so a model is never served against a different
//! hashing/sharding/topology setup than it was trained with. A salt
//! that disagrees with the plan the recorded config derives is
//! reported as a *plan* mismatch naming both sides (kind, shards,
//! dim), so an operator can tell "wrong worker count" from "corrupt
//! file".

use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::hashing::{fnv1a64, fnv1a64_iter};
use crate::learner::sgd::Sgd;
use crate::loss::Loss;
use crate::lr::LrSchedule;
use crate::model::Model;
use crate::serve::snapshot::ModelSnapshot;
use crate::sharding::{plan::WIRE_LEN as PLAN_WIRE_LEN, ShardPlan};

/// File magic: every checkpoint starts with these four bytes.
pub const MAGIC: &[u8; 4] = b"POLZ";
/// Current checkpoint format version.
pub const FORMAT_VERSION: u32 = 3;

/// Payload encodings (the byte after the format version).
pub const ENC_RAW: u8 = 0;
/// Encoding tag: sparse run-length weight tables.
pub const ENC_SPARSE: u8 = 1;

/// Caps keeping corrupted or hostile length fields from attempting
/// absurd allocations (the checksum authenticates integrity, not
/// intent — a crafted file can carry a valid checksum). The writer
/// enforces the same caps, so a checkpoint that saves successfully is
/// always loadable. `MAX_TOTAL_PARAMS` bounds the *aggregate* decoded
/// size: the zero-run encoding legitimately expands (that is its
/// point), but never past one `MAX_TABLE`-worth of parameters per file.
const MAX_PAYLOAD: u64 = 1 << 31;
const MAX_CFG_TEXT: u32 = 1 << 20;
const MAX_TABLE: u64 = 1 << 31;
const MAX_TABLES: u32 = 1 << 20;
const MAX_TOTAL_PARAMS: u64 = 1 << 31;

/// Zero gaps of at most this many slots are kept inline inside a run
/// (a gap of g zeros costs 4·g bytes inline vs 8 bytes of run header).
const RUN_MERGE_GAP: usize = 2;

/// What a checkpoint holds, ready to use: predictors warm-start and can
/// keep training (the step clocks are preserved). Callers that do not
/// care about the concrete type should use [`read_model`]/[`load_model`]
/// and stay on the [`Model`] trait.
pub enum Checkpoint {
    /// A single SGD learner.
    Sgd(Sgd),
    /// A full coordinator (per-node weight tables).
    Coordinator(Box<Coordinator>),
}

/// Parsed header + structural metadata (`pol checkpoint` inspection).
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    /// Format version the file was written with.
    pub format_version: u32,
    /// Weight-table encoding tag.
    pub encoding: u8,
    /// Checkpoint kind tag (SGD or coordinator).
    pub kind: u8,
    /// Digest of the run config that produced the model.
    pub config_digest: u64,
    /// Feature dimension.
    pub dim: u64,
    /// Hash salt the model was trained with.
    pub salt: u64,
    /// Instances trained when the checkpoint was taken.
    pub trained_instances: u64,
    /// Number of weight tables.
    pub tables: u32,
    /// Total parameters across all tables.
    pub total_params: u64,
    /// Human-readable config text embedded in the file.
    pub config_text: String,
    /// The shard plan recorded in the v3 header (`None` for plain-sgd
    /// checkpoints and for v1/v2 files, which predate the header
    /// plan).
    pub plan: Option<ShardPlan>,
    /// Tail of the writer's [`crate::obs::TraceRing`], appended as an
    /// optional `POLT` trailer *after* the checksummed payload — old
    /// readers stop at `payload_len` and never see it. Empty when the
    /// writer had no obs attached (or the file predates the trailer).
    pub trace: Vec<crate::obs::TraceEvent>,
}

impl CheckpointInfo {
    /// Human-readable kind tag.
    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            KIND_SGD => "sgd",
            KIND_CENTRAL => "central-coordinator",
            KIND_TREE => "tree-coordinator",
            _ => "unknown",
        }
    }

    /// Human-readable encoding tag.
    pub fn encoding_name(&self) -> &'static str {
        match self.encoding {
            ENC_RAW => "raw",
            ENC_SPARSE => "zero-run",
            _ => "unknown",
        }
    }
}

const KIND_SGD: u8 = 0;
const KIND_CENTRAL: u8 = 1;
const KIND_TREE: u8 = 2;

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Digest binding a model to its configuration *and* feature routing.
pub fn config_digest(cfg_text: &str, dim: u64, salt: u64) -> u64 {
    let mut bytes = cfg_text.as_bytes().to_vec();
    bytes.extend_from_slice(&dim.to_le_bytes());
    bytes.extend_from_slice(&salt.to_le_bytes());
    fnv1a64(&bytes)
}

/// Checksum covering the encoding byte, the header plan bytes (v3;
/// empty for v2), and the payload — a flipped header byte is caught
/// even though the payload bytes are intact.
fn payload_checksum(encoding: u8, plan_wire: &[u8], payload: &[u8]) -> u64 {
    fnv1a64_iter(
        std::iter::once(encoding)
            .chain(plan_wire.iter().copied())
            .chain(payload.iter().copied()),
    )
}

/// Header-plan kind byte for models without a sharded representation
/// (plain sgd).
const PLAN_NONE: u8 = 2;

fn encode_plan(plan: Option<&ShardPlan>) -> [u8; PLAN_WIRE_LEN] {
    match plan {
        Some(p) => p.to_wire(),
        None => {
            let mut none = [0u8; PLAN_WIRE_LEN];
            none[0] = PLAN_NONE;
            none
        }
    }
}

fn decode_plan(bytes: &[u8; PLAN_WIRE_LEN]) -> io::Result<Option<ShardPlan>> {
    if bytes[0] == PLAN_NONE && bytes[1..].iter().all(|&b| b == 0) {
        return Ok(None);
    }
    ShardPlan::from_wire(bytes)
        .map(Some)
        .ok_or_else(|| bad("malformed shard plan in checkpoint header"))
}

/// Provenance error for load-time plan comparisons: a salt (plan
/// signature) that disagrees with the plan the recorded config derives
/// means a different worker count or sharding scheme — not corruption
/// (the checksum already passed) — and the error says so, naming the
/// expected plan's kind, shard count, and dim.
fn plan_mismatch(expected: &ShardPlan, file_salt: u64) -> io::Error {
    bad(format!(
        "shard-plan signature mismatch: the recorded config derives {} \
         (signature {:#018x}), but the checkpoint was written under \
         signature {:#018x} — a different worker count or sharding \
         scheme, not file corruption (the checksum passed)",
        expected.describe(),
        expected.signature(),
        file_salt
    ))
}

// ------------------------------------------------------------- writing

/// Non-zero stretches of a weight table as `(start, count)` runs; zero
/// gaps of up to [`RUN_MERGE_GAP`] slots stay inline (cheaper than a
/// fresh run header). "Zero" means bit-pattern zero: `-0.0` is kept.
///
/// The scan itself is the dispatched zero-run scanner in
/// [`crate::simd`] (8-lane block skipping on AVX2); every tier emits
/// the identical run list, so the encoded bytes — and therefore the
/// checkpoint digests — are independent of the machine that wrote
/// them (pinned by the golden-byte test in `tests/test_simd.rs`).
fn sparse_runs(w: &[f32]) -> Vec<(u32, u32)> {
    crate::simd::zero_runs(w, RUN_MERGE_GAP)
}

fn push_table_raw(out: &mut Vec<u8>, steps: u64, w: &[f32]) {
    out.extend_from_slice(&steps.to_le_bytes());
    out.extend_from_slice(&(w.len() as u64).to_le_bytes());
    for &x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_table_sparse(
    out: &mut Vec<u8>,
    steps: u64,
    w: &[f32],
    runs: &[(u32, u32)],
) {
    out.extend_from_slice(&steps.to_le_bytes());
    out.extend_from_slice(&(w.len() as u64).to_le_bytes());
    // pol-lint: allow(L006, "run count bounded by table len <= MAX_TABLE")
    out.extend_from_slice(&(runs.len() as u32).to_le_bytes());
    for &(start, count) in runs {
        out.extend_from_slice(&start.to_le_bytes());
        out.extend_from_slice(&count.to_le_bytes());
        for &x in &w[start as usize..(start + count) as usize] {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Serialize the prelude + tables, picking the smaller table encoding
/// for the whole file. Both encoded sizes are computed arithmetically
/// first, so only the winning encoding is ever materialized (checkpoint
/// writes run on the training thread). The reader's structural caps
/// are enforced here too: a checkpoint that saves successfully is
/// always loadable — a too-large model errors at save time instead of
/// producing an unrecoverable file. Returns `(encoding, payload)`.
fn build_payload(
    kind: u8,
    cfg_text: &str,
    dim: u64,
    salt: u64,
    trained: u64,
    tables: &[(u64, &[f32])],
) -> io::Result<(u8, Vec<u8>)> {
    let cfg_len = u32::try_from(cfg_text.len())
        .ok()
        .filter(|&n| n <= MAX_CFG_TEXT)
        .ok_or_else(|| bad("config text exceeds the checkpoint format cap"))?;
    let table_count = u32::try_from(tables.len())
        .ok()
        .filter(|&n| n <= MAX_TABLES)
        .ok_or_else(|| bad("table count exceeds the checkpoint format cap"))?;
    let total_params: u64 = tables.iter().map(|&(_, w)| w.len() as u64).sum();
    if tables.iter().any(|&(_, w)| w.len() as u64 > MAX_TABLE)
        || total_params > MAX_TOTAL_PARAMS
    {
        return Err(bad(format!(
            "model too large for the checkpoint format ({total_params} \
             parameters; cap {MAX_TOTAL_PARAMS})"
        )));
    }
    let runs_per_table: Vec<Vec<(u32, u32)>> =
        tables.iter().map(|&(_, w)| sparse_runs(w)).collect();
    let mut raw_size = 0usize;
    let mut sparse_size = 0usize;
    for (&(_, w), runs) in tables.iter().zip(&runs_per_table) {
        raw_size += 16 + w.len() * 4;
        sparse_size += 16
            + 4
            + runs
                .iter()
                .map(|&(_, count)| 8 + count as usize * 4)
                .sum::<usize>();
    }
    let encoding = if sparse_size < raw_size { ENC_SPARSE } else { ENC_RAW };

    let section_size = sparse_size.min(raw_size);
    let mut payload =
        Vec::with_capacity(1 + 4 + cfg_text.len() + 28 + section_size);
    payload.push(kind);
    payload.extend_from_slice(&cfg_len.to_le_bytes());
    payload.extend_from_slice(cfg_text.as_bytes());
    payload.extend_from_slice(&dim.to_le_bytes());
    payload.extend_from_slice(&salt.to_le_bytes());
    payload.extend_from_slice(&trained.to_le_bytes());
    payload.extend_from_slice(&table_count.to_le_bytes());
    for (&(steps, w), runs) in tables.iter().zip(&runs_per_table) {
        if encoding == ENC_SPARSE {
            push_table_sparse(&mut payload, steps, w, runs);
        } else {
            push_table_raw(&mut payload, steps, w);
        }
    }
    if payload.len() as u64 > MAX_PAYLOAD {
        return Err(bad(format!(
            "model too large for the checkpoint format (payload {} bytes; \
             cap {MAX_PAYLOAD})",
            payload.len()
        )));
    }
    Ok((encoding, payload))
}

fn write_framed(
    out: &mut impl Write,
    cfg_text: &str,
    dim: u64,
    salt: u64,
    plan: Option<&ShardPlan>,
    encoding: u8,
    payload: &[u8],
) -> io::Result<()> {
    let plan_wire = encode_plan(plan);
    out.write_all(MAGIC)?;
    out.write_all(&FORMAT_VERSION.to_le_bytes())?;
    out.write_all(&[encoding])?;
    out.write_all(&plan_wire)?;
    out.write_all(&config_digest(cfg_text, dim, salt).to_le_bytes())?;
    out.write_all(
        &payload_checksum(encoding, &plan_wire, payload).to_le_bytes(),
    )?;
    out.write_all(&(payload.len() as u64).to_le_bytes())?;
    out.write_all(payload)
}

/// Canonical config text of an [`Sgd`] checkpoint. One definition only:
/// the config digest depends on byte-identical text, so writer and
/// snapshot construction must agree.
fn sgd_cfg_text(s: &Sgd) -> String {
    format!("kind = sgd\nloss = {}\nlr = {}\n", s.loss.name(), s.lr.spec())
}

/// The immutable serving snapshot of a plain [`Sgd`] learner (digest
/// included, so a server can verify provenance like any other model).
pub(crate) fn sgd_snapshot(s: &Sgd) -> ModelSnapshot {
    let digest = config_digest(&sgd_cfg_text(s), s.w.len() as u64, 0);
    ModelSnapshot::central(s.w.to_vec(), s.steps(), digest)
}

/// Serialize a plain [`Sgd`] learner.
pub fn write_sgd(s: &Sgd, out: &mut impl Write) -> io::Result<()> {
    let cfg_text = sgd_cfg_text(s);
    let dim = s.w.len() as u64;
    let (encoding, payload) = build_payload(
        KIND_SGD,
        &cfg_text,
        dim,
        0,
        s.steps(),
        &[(s.steps(), s.w.as_slice())],
    )?;
    write_framed(out, &cfg_text, dim, 0, None, encoding, &payload)
}

/// Serialize a trained [`Coordinator`] (centralized or tree).
pub fn write_coordinator(c: &Coordinator, out: &mut impl Write) -> io::Result<()> {
    let cfg_text = c.cfg.to_cfg_string();
    let dim = c.dim() as u64;
    let plan = c.plan();
    let salt = plan.signature();
    let (encoding, payload) = match c.central_weights() {
        Some(w) => build_payload(
            KIND_CENTRAL,
            &cfg_text,
            dim,
            salt,
            c.trained_instances(),
            &[(c.trained_instances(), w)],
        )?,
        None => {
            let tables: Vec<(u64, &[f32])> = c
                .nodes()
                .iter()
                .map(|n| (n.steps(), n.weights()))
                .collect();
            build_payload(
                KIND_TREE,
                &cfg_text,
                dim,
                salt,
                c.trained_instances(),
                &tables,
            )?
        }
    };
    write_framed(out, &cfg_text, dim, salt, Some(&plan), encoding, &payload)
}

/// Write a checkpoint atomically: serialize into `<path>.tmp`, fsync,
/// then rename over `path`, so readers never observe a half-written
/// file and a crash never clobbers the previous checkpoint.
pub fn save_atomic(
    path: &Path,
    write_fn: impl FnOnce(&mut dyn Write) -> io::Result<()>,
) -> io::Result<()> {
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    let result = (|| {
        let file = std::fs::File::create(&tmp)?;
        let mut out = io::BufWriter::new(file);
        write_fn(&mut out)?;
        out.flush()?;
        out.get_ref().sync_all()?;
        drop(out);
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Write `s` to `path` as a checkpoint.
pub fn save_sgd(s: &Sgd, path: &Path) -> io::Result<()> {
    save_atomic(path, |out| write_sgd(s, out))
}

/// Write `c` to `path` as a checkpoint.
pub fn save_coordinator(c: &Coordinator, path: &Path) -> io::Result<()> {
    save_atomic(path, |out| write_coordinator(c, out))
}

/// Background checkpointing cadence: every `every` trained instances,
/// the owning trainer serializes itself and hands the bytes to a
/// writer thread that performs the atomic file write (riding the same
/// per-instance tick the [`crate::serve::SnapshotPublisher`] uses, but
/// keeping disk latency and `fsync` off the training loop). Install
/// via [`crate::model::Model::install_checkpoint_sink`] or
/// `SessionBuilder::checkpoint_every`; call [`Self::flush`] (or
/// `Model::finish_checkpoints`) before relying on the file.
pub struct CheckpointSink {
    path: PathBuf,
    every: u64,
    next_at: u64,
    /// Successful background writes so far (shared with
    /// [`Self::writes_handle`] observers).
    writes: Arc<AtomicU64>,
    /// The in-flight background write, if any (at most one).
    pending: Option<std::thread::JoinHandle<()>>,
}

impl CheckpointSink {
    /// A sink that checkpoints to `path` every `every` instances.
    pub fn new(path: impl Into<PathBuf>, every: u64) -> CheckpointSink {
        let every = every.max(1);
        CheckpointSink {
            path: path.into(),
            every,
            next_at: every,
            writes: Arc::new(AtomicU64::new(0)),
            pending: None,
        }
    }

    /// Re-arm the cadence from a training-stream position (warm starts:
    /// first write lands `every` instances after `trained`, not at the
    /// absolute position `every`).
    pub fn arm(&mut self, trained: u64) {
        self.next_at = trained + self.every;
    }

    /// Destination path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Write cadence in instances.
    pub fn every(&self) -> u64 {
        self.every
    }

    /// Whether the cadence says a checkpoint is due at this position.
    pub fn tick(&self, trained: u64) -> bool {
        trained >= self.next_at
    }

    /// Successful background writes so far.
    pub fn writes(&self) -> u64 {
        // pol-lint: allow(L002, "monotonic write counter, no publication")
        self.writes.load(Ordering::Relaxed)
    }

    /// A live handle to the write counter (observable after the sink is
    /// moved into a trainer).
    pub fn writes_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.writes)
    }

    /// Write one checkpoint atomically *on the calling thread* and
    /// re-arm the cadence. The cadence re-arms even on failure so a
    /// persistently failing path does not retry on every instance.
    pub fn write_with(
        &mut self,
        trained: u64,
        write_fn: impl FnOnce(&mut dyn Write) -> io::Result<()>,
    ) -> io::Result<()> {
        self.flush();
        self.next_at = trained + self.every;
        save_atomic(&self.path, write_fn)?;
        // pol-lint: allow(L002, "monotonic write counter, no publication")
        self.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Hand one already-serialized checkpoint to the background writer
    /// and re-arm the cadence. At most one write is ever in flight: a
    /// new write first joins the previous one, so a slow disk
    /// backpressures the cadence instead of stacking threads. Write
    /// failures log to stderr (background durability is best-effort —
    /// end-of-training saves go through [`save_atomic`] directly).
    pub fn write_async(&mut self, trained: u64, bytes: Vec<u8>) {
        self.next_at = trained + self.every;
        self.flush();
        let path = self.path.clone();
        let writes = Arc::clone(&self.writes);
        self.pending = Some(std::thread::spawn(move || {
            match save_atomic(&path, |out| out.write_all(&bytes)) {
                Ok(()) => {
                    // pol-lint: allow(L002, "monotonic write counter")
                    writes.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    eprintln!("background checkpoint to {path:?} failed: {e}")
                }
            }
        }));
    }

    /// Wait for any in-flight background write to land.
    pub fn flush(&mut self) {
        if let Some(h) = self.pending.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CheckpointSink {
    fn drop(&mut self) {
        self.flush();
    }
}

// ------------------------------------------------------------- reading

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| bad("truncated payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(crate::bytes::le_u32(self.take(4)?))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(crate::bytes::le_u64(self.take(8)?))
    }

    fn f32_into(&mut self, out: &mut [f32]) -> io::Result<()> {
        let raw = self.take(out.len() * 4)?;
        for (slot, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *slot = crate::bytes::le_f32(c);
        }
        Ok(())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

struct RawCheckpoint {
    info: CheckpointInfo,
    /// (step clock, weights) per table.
    tables: Vec<(u64, Vec<f32>)>,
}

fn read_table(
    cur: &mut Cursor,
    encoding: u8,
    budget: u64,
) -> io::Result<(u64, Vec<f32>)> {
    let steps = cur.u64()?;
    let len = cur.u64()?;
    if len > MAX_TABLE || len > budget {
        return Err(bad("weight table exceeds cap"));
    }
    let mut w = vec![0.0f32; len as usize];
    match encoding {
        ENC_RAW => cur.f32_into(&mut w)?,
        ENC_SPARSE => {
            let nruns = cur.u32()?;
            if u64::from(nruns) > len {
                return Err(bad("zero-run count exceeds table length"));
            }
            let mut prev_end = 0u64;
            for _ in 0..nruns {
                let start = u64::from(cur.u32()?);
                let count = u64::from(cur.u32()?);
                if count == 0 {
                    return Err(bad("empty zero-run"));
                }
                if start < prev_end || start + count > len {
                    return Err(bad("zero-run out of bounds"));
                }
                cur.f32_into(
                    &mut w[start as usize..(start + count) as usize],
                )?;
                prev_end = start + count;
            }
        }
        e => return Err(bad(format!("unknown payload encoding {e}"))),
    }
    Ok((steps, w))
}

fn read_raw(inp: &mut impl Read) -> io::Result<RawCheckpoint> {
    let mut head = [0u8; 8];
    inp.read_exact(&mut head).map_err(|_| bad("truncated header"))?;
    if &head[0..4] != MAGIC {
        return Err(bad("bad magic (not a .polz checkpoint)"));
    }
    let format_version = crate::bytes::le_u32(&head[4..8]);
    // version 1: no encoding byte, raw tables, checksum over the payload
    // alone; version 2: encoding byte after the version, checksum over
    // (encoding ‖ payload); version 3: shard plan after the encoding
    // byte, checksum over (encoding ‖ plan ‖ payload)
    let mut header_plan: Option<ShardPlan> = None;
    let mut plan_wire: Vec<u8> = Vec::new();
    let (encoding, digest, checksum, payload_len) = match format_version {
        1 => {
            let mut rest = [0u8; 24];
            inp.read_exact(&mut rest).map_err(|_| bad("truncated header"))?;
            (
                ENC_RAW,
                crate::bytes::le_u64(&rest[0..8]),
                crate::bytes::le_u64(&rest[8..16]),
                crate::bytes::le_u64(&rest[16..24]),
            )
        }
        2 => {
            let mut rest = [0u8; 25];
            inp.read_exact(&mut rest).map_err(|_| bad("truncated header"))?;
            (
                rest[0],
                crate::bytes::le_u64(&rest[1..9]),
                crate::bytes::le_u64(&rest[9..17]),
                crate::bytes::le_u64(&rest[17..25]),
            )
        }
        3 => {
            let mut rest = [0u8; 25 + PLAN_WIRE_LEN];
            inp.read_exact(&mut rest).map_err(|_| bad("truncated header"))?;
            let mut wire = [0u8; PLAN_WIRE_LEN];
            wire.copy_from_slice(&rest[1..1 + PLAN_WIRE_LEN]);
            header_plan = decode_plan(&wire)?;
            plan_wire = wire.to_vec();
            let p = 1 + PLAN_WIRE_LEN;
            (
                rest[0],
                crate::bytes::le_u64(&rest[p..p + 8]),
                crate::bytes::le_u64(&rest[p + 8..p + 16]),
                crate::bytes::le_u64(&rest[p + 16..p + 24]),
            )
        }
        v => return Err(bad(format!("unsupported checkpoint version {v}"))),
    };
    if encoding > ENC_SPARSE {
        return Err(bad(format!("unknown payload encoding {encoding}")));
    }
    if payload_len > MAX_PAYLOAD {
        return Err(bad(format!("payload length {payload_len} exceeds cap")));
    }
    let mut payload = Vec::new();
    inp.take(payload_len).read_to_end(&mut payload)?;
    if payload.len() as u64 != payload_len {
        return Err(bad(format!(
            "truncated payload: expected {payload_len} bytes, got {}",
            payload.len()
        )));
    }
    let expect = if format_version == 1 {
        fnv1a64(&payload)
    } else {
        payload_checksum(encoding, &plan_wire, &payload)
    };
    if expect != checksum {
        return Err(bad("payload checksum mismatch (corrupted checkpoint)"));
    }

    let mut cur = Cursor { buf: &payload, pos: 0 };
    let kind = cur.u8()?;
    if kind > KIND_TREE {
        return Err(bad(format!("unknown checkpoint kind {kind}")));
    }
    let cfg_len = cur.u32()?;
    if cfg_len > MAX_CFG_TEXT {
        return Err(bad("config text exceeds cap"));
    }
    let config_text = String::from_utf8(cur.take(cfg_len as usize)?.to_vec())
        .map_err(|_| bad("config text is not utf-8"))?;
    let dim = cur.u64()?;
    let salt = cur.u64()?;
    let trained_instances = cur.u64()?;
    if config_digest(&config_text, dim, salt) != digest {
        return Err(bad("config digest mismatch"));
    }
    let ntables = cur.u32()?;
    if ntables > MAX_TABLES {
        return Err(bad("table count exceeds cap"));
    }
    let mut tables = Vec::with_capacity(ntables as usize);
    let mut total_params = 0u64;
    for _ in 0..ntables {
        // pass the remaining aggregate budget down so a hostile file
        // cannot stack many max-size sparse tables into one huge alloc
        let (steps, w) =
            read_table(&mut cur, encoding, MAX_TOTAL_PARAMS - total_params)?;
        total_params += w.len() as u64;
        tables.push((steps, w));
    }
    if !cur.done() {
        return Err(bad("trailing bytes after payload"));
    }
    Ok(RawCheckpoint {
        info: CheckpointInfo {
            format_version,
            encoding,
            kind,
            config_digest: digest,
            dim,
            salt,
            trained_instances,
            tables: ntables,
            total_params,
            config_text,
            plan: header_plan,
            trace: Vec::new(),
        },
        tables,
    })
}

/// Minimal `key = value` lookup for the sgd-kind config text.
fn cfg_lookup<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    text.lines().find_map(|line| {
        let (k, v) = line.split_once('=')?;
        if k.trim() == key {
            Some(v.trim())
        } else {
            None
        }
    })
}

/// Derive the shard plan from the recorded config + dim and hold it
/// against the file's salt and (v3) header plan. Runs *before* the
/// model is constructed, so a wrong-worker-count file fails with a
/// provenance error naming both plans instead of a table-shape error.
fn verify_plan(
    info: &CheckpointInfo,
    cfg: &RunConfig,
) -> io::Result<ShardPlan> {
    let derived = ShardPlan::for_topology(&cfg.topology, info.dim as usize);
    if derived.signature() != info.salt {
        return Err(plan_mismatch(&derived, info.salt));
    }
    if let Some(header) = info.plan {
        if header != derived {
            return Err(bad(format!(
                "checkpoint header plan ({}) disagrees with the plan its \
                 recorded config derives ({})",
                header.describe(),
                derived.describe()
            )));
        }
    }
    Ok(derived)
}

/// Deserialize a checkpoint from a reader.
pub fn read(inp: &mut impl Read) -> io::Result<Checkpoint> {
    let raw = read_raw(inp)?;
    let info = &raw.info;
    match info.kind {
        KIND_SGD => {
            if info.plan.is_some() {
                return Err(bad(
                    "sgd checkpoint must not carry a shard plan",
                ));
            }
            let loss = cfg_lookup(&info.config_text, "loss")
                .and_then(Loss::parse)
                .ok_or_else(|| bad("sgd checkpoint missing loss"))?;
            let lr = cfg_lookup(&info.config_text, "lr")
                .and_then(LrSchedule::parse_spec)
                .ok_or_else(|| bad("sgd checkpoint missing lr"))?;
            let [(steps, w)] = <[_; 1]>::try_from(raw.tables)
                .map_err(|_| bad("sgd checkpoint must hold one table"))?;
            if w.len() as u64 != info.dim {
                return Err(bad("sgd table length disagrees with dim"));
            }
            Ok(Checkpoint::Sgd(Sgd::from_parts(w, loss, lr, steps)))
        }
        KIND_CENTRAL => {
            let cfg = parse_run_config(&info.config_text)?;
            verify_plan(info, &cfg)?;
            let [(_, w)] = <[_; 1]>::try_from(raw.tables)
                .map_err(|_| bad("central checkpoint must hold one table"))?;
            if w.len() as u64 != info.dim {
                return Err(bad("central table length disagrees with dim"));
            }
            let c = Coordinator::restore_central(
                cfg,
                info.dim as usize,
                w,
                info.trained_instances,
            )
            .map_err(bad)?;
            Ok(Checkpoint::Coordinator(Box::new(c)))
        }
        KIND_TREE => {
            let cfg = parse_run_config(&info.config_text)?;
            verify_plan(info, &cfg)?;
            let c = Coordinator::restore_tree(
                cfg,
                info.dim as usize,
                raw.tables,
                info.trained_instances,
            )
            .map_err(bad)?;
            Ok(Checkpoint::Coordinator(Box::new(c)))
        }
        k => Err(bad(format!("unknown checkpoint kind {k}"))),
    }
}

fn parse_run_config(text: &str) -> io::Result<RunConfig> {
    RunConfig::from_str_cfg(text)
        .map_err(|e| bad(format!("bad checkpoint config: {e}")))
}

/// Load a checkpoint from a file.
pub fn load(path: &Path) -> io::Result<Checkpoint> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    read(&mut f)
}

/// Deserialize a checkpoint straight to a [`Model`] trait object — the
/// one place the kind byte turns into a concrete type.
pub fn read_model(inp: &mut impl Read) -> io::Result<Box<dyn Model>> {
    Ok(match read(inp)? {
        Checkpoint::Sgd(s) => Box::new(s) as Box<dyn Model>,
        Checkpoint::Coordinator(c) => c,
    })
}

/// Load a [`Model`] trait object from a file.
pub fn load_model(path: &Path) -> io::Result<Box<dyn Model>> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    read_model(&mut f)
}

/// Parse structure + metadata without building the model (`pol
/// checkpoint` inspection; still verifies checksum and digest). Also
/// decodes the optional `POLT` trace trailer after the payload — a
/// file without one yields an empty trace; a *corrupt* trailer is an
/// error (the writer only ever appends whole, checksummed trailers).
pub fn inspect(path: &Path) -> io::Result<CheckpointInfo> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    let mut info = read_raw(&mut f)?.info;
    info.trace = crate::obs::trace::read_trailer(&mut f)?;
    Ok(info)
}

impl Checkpoint {
    /// The immutable serving view of this checkpoint.
    pub fn into_snapshot(self) -> ModelSnapshot {
        match self {
            Checkpoint::Sgd(s) => sgd_snapshot(&s),
            Checkpoint::Coordinator(c) => c.snapshot(),
        }
    }

    /// Predict without consuming the checkpoint. Loaded models face
    /// arbitrary caller input, so this is the bounds-checked request
    /// surface (out-of-range indices contribute nothing; in-range
    /// inputs score bit-identically to the training-side predict).
    pub fn predict(&self, x: &[crate::linalg::SparseFeat]) -> f64 {
        match self {
            Checkpoint::Sgd(s) => {
                crate::serve::snapshot::request_dot(&s.w, x)
            }
            Checkpoint::Coordinator(c) => {
                let mut scratch =
                    crate::serve::snapshot::PredictScratch::default();
                c.predict_request(x, &mut scratch)
            }
        }
    }

    /// Feature dimension of the contained model.
    pub fn dim(&self) -> usize {
        match self {
            Checkpoint::Sgd(s) => s.w.len(),
            Checkpoint::Coordinator(c) => c.dim(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UpdateRule;
    use crate::data::synth::{RcvLikeGen, SynthConfig};
    use crate::topology::Topology;

    fn trained_sgd() -> Sgd {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 500,
            features: 200,
            density: 10,
            hash_bits: 10,
            ..Default::default()
        })
        .generate();
        let mut s = Sgd::new(
            ds.dim,
            Loss::Logistic,
            LrSchedule::inv_sqrt(2.0, 10.0),
        );
        for inst in ds.iter() {
            s.learn(&inst.features, inst.label);
        }
        s
    }

    #[test]
    fn sgd_roundtrip_bit_identical() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Sgd(s) => s,
            _ => panic!("wrong kind"),
        };
        assert_eq!(back.w, s.w);
        assert_eq!(back.steps(), s.steps());
        assert_eq!(back.loss, s.loss);
        assert_eq!(back.lr, s.lr);
    }

    #[test]
    fn tree_roundtrip_identical_predictions() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 1_000,
            features: 300,
            density: 12,
            hash_bits: 11,
            ..Default::default()
        })
        .generate();
        let cfg = RunConfig {
            topology: Topology::TwoLayer { shards: 4 },
            rule: UpdateRule::Backprop { multiplier: 2.0 },
            loss: Loss::Logistic,
            lr: LrSchedule::inv_sqrt(2.0, 1.0),
            clip01: false,
            tau: 32,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, ds.dim);
        c.train(&ds);
        let mut buf = Vec::new();
        write_coordinator(&c, &mut buf).unwrap();
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Coordinator(c) => c,
            _ => panic!("wrong kind"),
        };
        for inst in ds.iter().take(100) {
            let a = c.predict(&inst.features);
            let b = back.predict(&inst.features);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(back.trained_instances(), c.trained_instances());
    }

    #[test]
    fn central_roundtrip_identical_predictions() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 800,
            features: 200,
            density: 10,
            hash_bits: 10,
            ..Default::default()
        })
        .generate();
        let cfg = RunConfig {
            rule: UpdateRule::Minibatch { batch: 64 },
            loss: Loss::Logistic,
            clip01: false,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, ds.dim);
        c.train(&ds);
        let mut buf = Vec::new();
        write_coordinator(&c, &mut buf).unwrap();
        let back = read(&mut buf.as_slice()).unwrap();
        for inst in ds.iter().take(50) {
            assert_eq!(
                c.predict(&inst.features).to_bits(),
                back.predict(&inst.features).to_bits()
            );
        }
    }

    #[test]
    fn truncation_errors_cleanly() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        for cut in [0, 3, 8, 31, 33, 40, buf.len() - 1] {
            let err = read(&mut &buf[..cut]).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "cut {cut}: {err}");
        }
    }

    #[test]
    fn corruption_detected_by_checksum() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        // flip one byte deep in the weight payload
        let idx = buf.len() - 5;
        buf[idx] ^= 0x40;
        let err = read(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn flipped_encoding_byte_detected() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        // byte 8 is the payload-encoding byte; the checksum covers it
        buf[8] ^= 0x01;
        let err = read(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn sparse_runs_shapes() {
        assert!(sparse_runs(&[0.0; 8]).is_empty());
        assert_eq!(sparse_runs(&[1.0, 2.0]), vec![(0, 2)]);
        // short gaps stay inline; long gaps split runs
        assert_eq!(sparse_runs(&[1.0, 0.0, 0.0, 2.0]), vec![(0, 4)]);
        assert_eq!(
            sparse_runs(&[1.0, 0.0, 0.0, 0.0, 2.0]),
            vec![(0, 1), (4, 1)]
        );
        // -0.0 has a non-zero bit pattern and must be kept
        assert_eq!(sparse_runs(&[0.0, -0.0, 0.0]), vec![(1, 1)]);
        // trailing zeros after the last non-zero are dropped
        assert_eq!(sparse_runs(&[0.0, 3.0, 0.0, 0.0, 0.0]), vec![(1, 1)]);
    }

    #[test]
    fn zero_heavy_table_compresses_and_roundtrips() {
        // a sparse online learner over a wide hashed space: almost all
        // slots untouched
        let mut w = vec![0.0f32; 16_384];
        w[7] = 1.5;
        w[8] = -0.25;
        w[5_000] = 3.0;
        w[16_383] = -0.0;
        let s = Sgd::from_parts(
            w.clone(),
            Loss::Logistic,
            LrSchedule::constant(0.1),
            42,
        );
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        assert!(
            buf.len() < 16_384 * 4 / 10,
            "zero-heavy table should compress well, got {} bytes",
            buf.len()
        );
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Sgd(b) => b,
            _ => panic!("wrong kind"),
        };
        assert_eq!(back.steps(), 42);
        assert_eq!(back.w.len(), w.len());
        for (a, b) in back.w.iter().zip(&w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dense_table_falls_back_to_raw() {
        let w: Vec<f32> = (0..512).map(|i| 0.01 * (i + 1) as f32).collect();
        let s = Sgd::from_parts(
            w.clone(),
            Loss::Squared,
            LrSchedule::constant(0.1),
            7,
        );
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        assert_eq!(buf[8], ENC_RAW, "dense tables should pick raw encoding");
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Sgd(b) => b,
            _ => panic!("wrong kind"),
        };
        assert_eq!(back.w, w);
    }

    #[test]
    fn format_v1_files_still_read() {
        // hand-write the version-1 framing (no encoding byte, raw
        // tables, checksum over the payload alone) and read it back
        let s = trained_sgd();
        let cfg_text = sgd_cfg_text(&s);
        let dim = s.w.len() as u64;
        let mut payload = Vec::new();
        payload.push(0u8); // kind sgd
        payload.extend_from_slice(&(cfg_text.len() as u32).to_le_bytes());
        payload.extend_from_slice(cfg_text.as_bytes());
        payload.extend_from_slice(&dim.to_le_bytes());
        payload.extend_from_slice(&0u64.to_le_bytes());
        payload.extend_from_slice(&s.steps().to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&s.steps().to_le_bytes());
        payload.extend_from_slice(&(s.w.len() as u64).to_le_bytes());
        for &x in &s.w {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(
            &config_digest(&cfg_text, dim, 0).to_le_bytes(),
        );
        buf.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&payload);
        let info_src = buf.clone();
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Sgd(b) => b,
            _ => panic!("wrong kind"),
        };
        assert_eq!(back.w, s.w);
        assert_eq!(back.steps(), s.steps());
        // inspect reports the old version + raw encoding
        let raw = read_raw(&mut info_src.as_slice()).unwrap();
        assert_eq!(raw.info.format_version, 1);
        assert_eq!(raw.info.encoding_name(), "raw");
    }

    #[test]
    fn inspect_reports_meta() {
        let s = trained_sgd();
        let dir = std::env::temp_dir().join("pol_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.polz");
        save_sgd(&s, &path).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.format_version, FORMAT_VERSION);
        assert_eq!(info.kind_name(), "sgd");
        assert_eq!(info.dim, s.w.len() as u64);
        assert_eq!(info.tables, 1);
        assert_eq!(info.total_params, s.w.len() as u64);
        assert!(info.config_text.contains("loss = logistic"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_sink_cadence_and_atomic_write() {
        let dir = std::env::temp_dir().join("pol_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bg.polz");
        std::fs::remove_file(&path).ok();
        let s = trained_sgd();
        let mut sink = CheckpointSink::new(&path, 100);
        let handle = sink.writes_handle();
        assert!(!sink.tick(99));
        assert!(sink.tick(100));
        sink.write_with(100, |out| write_sgd(&s, out)).unwrap();
        assert_eq!(handle.load(Ordering::Relaxed), 1);
        assert!(!sink.tick(150), "cadence must re-arm after a write");
        assert!(sink.tick(200));
        // the written file is a valid checkpoint, and no .tmp remains
        let back = load(&path).unwrap();
        assert_eq!(back.dim(), s.w.len());
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists());
        std::fs::remove_file(&path).ok();
    }
}
