//! `.polz` — the versioned, self-describing checkpoint format.
//!
//! Any trained topology round-trips to disk and warm-starts: a plain
//! [`Sgd`], a centralized (Minibatch/CG/SGD) coordinator, or a full
//! feature-sharded node tree. The format is self-describing (the
//! canonical config text rides along) and tamper-evident (whole-payload
//! FNV-1a checksum + config digest), so truncated or corrupted bytes
//! come back as [`io::Error`]s — never a panic, never a silently wrong
//! model.
//!
//! Layout (all integers little-endian):
//! ```text
//! magic "POLZ" | u32 format version | u64 config digest
//! u64 payload checksum (FNV-1a) | u64 payload length
//! payload:
//!   u8 kind (0 = sgd, 1 = central coordinator, 2 = tree coordinator)
//!   u32 config-text length | config text (canonical `key = value`)
//!   u64 dim | u64 routing salt (sharder signature; 0 for sgd/central)
//!   u64 trained instances
//!   u32 table count
//!   per table: u64 step clock | u64 length | length × f32 weights
//! ```
//! The config digest is FNV-1a over (config text ‖ dim ‖ salt) — the
//! serving process verifies it so a model is never served against a
//! different hashing/sharding/topology setup than it was trained with.

use std::io::{self, Read, Write};

use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::hashing::fnv1a64;
use crate::learner::sgd::Sgd;
use crate::learner::OnlineLearner;
use crate::loss::Loss;
use crate::lr::LrSchedule;
use crate::serve::snapshot::ModelSnapshot;

pub const MAGIC: &[u8; 4] = b"POLZ";
pub const FORMAT_VERSION: u32 = 1;

/// Caps keeping corrupted length fields from attempting absurd
/// allocations before the checksum is even checked.
const MAX_PAYLOAD: u64 = 1 << 31;
const MAX_CFG_TEXT: u32 = 1 << 20;
const MAX_TABLE: u64 = 1 << 31;
const MAX_TABLES: u32 = 1 << 20;

/// What a checkpoint holds, ready to use: predictors warm-start and can
/// keep training (the step clocks are preserved).
pub enum Checkpoint {
    Sgd(Sgd),
    Coordinator(Box<Coordinator>),
}

/// Parsed header + structural metadata (`pol checkpoint` inspection).
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    pub format_version: u32,
    pub kind: u8,
    pub config_digest: u64,
    pub dim: u64,
    pub salt: u64,
    pub trained_instances: u64,
    pub tables: u32,
    pub total_params: u64,
    pub config_text: String,
}

impl CheckpointInfo {
    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            KIND_SGD => "sgd",
            KIND_CENTRAL => "central-coordinator",
            KIND_TREE => "tree-coordinator",
            _ => "unknown",
        }
    }
}

const KIND_SGD: u8 = 0;
const KIND_CENTRAL: u8 = 1;
const KIND_TREE: u8 = 2;

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Digest binding a model to its configuration *and* feature routing.
pub fn config_digest(cfg_text: &str, dim: u64, salt: u64) -> u64 {
    let mut bytes = cfg_text.as_bytes().to_vec();
    bytes.extend_from_slice(&dim.to_le_bytes());
    bytes.extend_from_slice(&salt.to_le_bytes());
    fnv1a64(&bytes)
}

// ------------------------------------------------------------- writing

fn push_table(payload: &mut Vec<u8>, steps: u64, w: &[f32]) {
    payload.extend_from_slice(&steps.to_le_bytes());
    payload.extend_from_slice(&(w.len() as u64).to_le_bytes());
    for &x in w {
        payload.extend_from_slice(&x.to_le_bytes());
    }
}

fn build_payload(
    kind: u8,
    cfg_text: &str,
    dim: u64,
    salt: u64,
    trained: u64,
    tables: &[(u64, &[f32])],
) -> Vec<u8> {
    let wlen: usize = tables.iter().map(|(_, w)| w.len() * 4 + 16).sum();
    let mut payload = Vec::with_capacity(1 + 4 + cfg_text.len() + 28 + wlen);
    payload.push(kind);
    payload.extend_from_slice(&(cfg_text.len() as u32).to_le_bytes());
    payload.extend_from_slice(cfg_text.as_bytes());
    payload.extend_from_slice(&dim.to_le_bytes());
    payload.extend_from_slice(&salt.to_le_bytes());
    payload.extend_from_slice(&trained.to_le_bytes());
    payload.extend_from_slice(&(tables.len() as u32).to_le_bytes());
    for &(steps, w) in tables {
        push_table(&mut payload, steps, w);
    }
    payload
}

fn write_framed(
    out: &mut impl Write,
    cfg_text: &str,
    dim: u64,
    salt: u64,
    payload: &[u8],
) -> io::Result<()> {
    out.write_all(MAGIC)?;
    out.write_all(&FORMAT_VERSION.to_le_bytes())?;
    out.write_all(&config_digest(cfg_text, dim, salt).to_le_bytes())?;
    out.write_all(&fnv1a64(payload).to_le_bytes())?;
    out.write_all(&(payload.len() as u64).to_le_bytes())?;
    out.write_all(payload)
}

/// Canonical config text of an [`Sgd`] checkpoint. One definition only:
/// the config digest depends on byte-identical text, so writer and
/// snapshot construction must agree.
fn sgd_cfg_text(s: &Sgd) -> String {
    format!("kind = sgd\nloss = {}\nlr = {}\n", s.loss.name(), s.lr.spec())
}

/// Serialize a plain [`Sgd`] learner.
pub fn write_sgd(s: &Sgd, out: &mut impl Write) -> io::Result<()> {
    let cfg_text = sgd_cfg_text(s);
    let dim = s.w.len() as u64;
    let payload = build_payload(
        KIND_SGD,
        &cfg_text,
        dim,
        0,
        s.steps(),
        &[(s.steps(), &s.w)],
    );
    write_framed(out, &cfg_text, dim, 0, &payload)
}

/// Serialize a trained [`Coordinator`] (centralized or tree).
pub fn write_coordinator(c: &Coordinator, out: &mut impl Write) -> io::Result<()> {
    let cfg_text = c.cfg.to_cfg_string();
    let dim = c.dim() as u64;
    let salt = c.sharder_signature();
    let payload = match c.central_weights() {
        Some(w) => build_payload(
            KIND_CENTRAL,
            &cfg_text,
            dim,
            salt,
            c.trained_instances(),
            &[(c.trained_instances(), w)],
        ),
        None => {
            let tables: Vec<(u64, &[f32])> = c
                .nodes()
                .iter()
                .map(|n| (n.steps(), n.weights()))
                .collect();
            build_payload(
                KIND_TREE,
                &cfg_text,
                dim,
                salt,
                c.trained_instances(),
                &tables,
            )
        }
    };
    write_framed(out, &cfg_text, dim, salt, &payload)
}

pub fn save_sgd(s: &Sgd, path: &std::path::Path) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    write_sgd(s, &mut f)?;
    f.flush()
}

pub fn save_coordinator(c: &Coordinator, path: &std::path::Path) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    write_coordinator(c, &mut f)?;
    f.flush()
}

// ------------------------------------------------------------- reading

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| bad("truncated payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

struct RawCheckpoint {
    info: CheckpointInfo,
    /// (step clock, weights) per table.
    tables: Vec<(u64, Vec<f32>)>,
}

fn read_raw(inp: &mut impl Read) -> io::Result<RawCheckpoint> {
    let mut header = [0u8; 32];
    inp.read_exact(&mut header)
        .map_err(|_| bad("truncated header"))?;
    if &header[0..4] != MAGIC {
        return Err(bad("bad magic (not a .polz checkpoint)"));
    }
    let format_version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if format_version != FORMAT_VERSION {
        return Err(bad(format!(
            "unsupported checkpoint version {format_version}"
        )));
    }
    let digest = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let checksum = u64::from_le_bytes(header[16..24].try_into().unwrap());
    let payload_len = u64::from_le_bytes(header[24..32].try_into().unwrap());
    if payload_len > MAX_PAYLOAD {
        return Err(bad(format!("payload length {payload_len} exceeds cap")));
    }
    let mut payload = Vec::new();
    inp.take(payload_len).read_to_end(&mut payload)?;
    if payload.len() as u64 != payload_len {
        return Err(bad(format!(
            "truncated payload: expected {payload_len} bytes, got {}",
            payload.len()
        )));
    }
    if fnv1a64(&payload) != checksum {
        return Err(bad("payload checksum mismatch (corrupted checkpoint)"));
    }

    let mut cur = Cursor { buf: &payload, pos: 0 };
    let kind = cur.u8()?;
    if kind > KIND_TREE {
        return Err(bad(format!("unknown checkpoint kind {kind}")));
    }
    let cfg_len = cur.u32()?;
    if cfg_len > MAX_CFG_TEXT {
        return Err(bad("config text exceeds cap"));
    }
    let config_text = String::from_utf8(cur.take(cfg_len as usize)?.to_vec())
        .map_err(|_| bad("config text is not utf-8"))?;
    let dim = cur.u64()?;
    let salt = cur.u64()?;
    let trained_instances = cur.u64()?;
    if config_digest(&config_text, dim, salt) != digest {
        return Err(bad("config digest mismatch"));
    }
    let ntables = cur.u32()?;
    if ntables > MAX_TABLES {
        return Err(bad("table count exceeds cap"));
    }
    let mut tables = Vec::with_capacity(ntables as usize);
    let mut total_params = 0u64;
    for _ in 0..ntables {
        let steps = cur.u64()?;
        let len = cur.u64()?;
        if len > MAX_TABLE {
            return Err(bad("weight table exceeds cap"));
        }
        let raw = cur.take(len as usize * 4)?;
        let mut w = Vec::with_capacity(len as usize);
        for c in raw.chunks_exact(4) {
            w.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        total_params += len;
        tables.push((steps, w));
    }
    if !cur.done() {
        return Err(bad("trailing bytes after payload"));
    }
    Ok(RawCheckpoint {
        info: CheckpointInfo {
            format_version,
            kind,
            config_digest: digest,
            dim,
            salt,
            trained_instances,
            tables: ntables,
            total_params,
            config_text,
        },
        tables,
    })
}

/// Minimal `key = value` lookup for the sgd-kind config text.
fn cfg_lookup<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    text.lines().find_map(|line| {
        let (k, v) = line.split_once('=')?;
        if k.trim() == key {
            Some(v.trim())
        } else {
            None
        }
    })
}

/// Deserialize a checkpoint from a reader.
pub fn read(inp: &mut impl Read) -> io::Result<Checkpoint> {
    let raw = read_raw(inp)?;
    let info = &raw.info;
    match info.kind {
        KIND_SGD => {
            let loss = cfg_lookup(&info.config_text, "loss")
                .and_then(Loss::parse)
                .ok_or_else(|| bad("sgd checkpoint missing loss"))?;
            let lr = cfg_lookup(&info.config_text, "lr")
                .and_then(LrSchedule::parse_spec)
                .ok_or_else(|| bad("sgd checkpoint missing lr"))?;
            let [(steps, w)] = <[_; 1]>::try_from(raw.tables)
                .map_err(|_| bad("sgd checkpoint must hold one table"))?;
            if w.len() as u64 != info.dim {
                return Err(bad("sgd table length disagrees with dim"));
            }
            Ok(Checkpoint::Sgd(Sgd::from_parts(w, loss, lr, steps)))
        }
        KIND_CENTRAL => {
            let cfg = parse_run_config(&info.config_text)?;
            let [(_, w)] = <[_; 1]>::try_from(raw.tables)
                .map_err(|_| bad("central checkpoint must hold one table"))?;
            if w.len() as u64 != info.dim {
                return Err(bad("central table length disagrees with dim"));
            }
            let c = Coordinator::restore_central(
                cfg,
                info.dim as usize,
                w,
                info.trained_instances,
            )
            .map_err(bad)?;
            Ok(Checkpoint::Coordinator(Box::new(c)))
        }
        KIND_TREE => {
            let cfg = parse_run_config(&info.config_text)?;
            let c = Coordinator::restore_tree(
                cfg,
                info.dim as usize,
                raw.tables,
                info.trained_instances,
            )
            .map_err(bad)?;
            if c.sharder_signature() != info.salt {
                return Err(bad("sharder signature mismatch"));
            }
            Ok(Checkpoint::Coordinator(Box::new(c)))
        }
        k => Err(bad(format!("unknown checkpoint kind {k}"))),
    }
}

fn parse_run_config(text: &str) -> io::Result<RunConfig> {
    RunConfig::from_str_cfg(text)
        .map_err(|e| bad(format!("bad checkpoint config: {e}")))
}

/// Load a checkpoint from a file.
pub fn load(path: &std::path::Path) -> io::Result<Checkpoint> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    read(&mut f)
}

/// Parse structure + metadata without building the model (`pol
/// checkpoint` inspection; still verifies checksum and digest).
pub fn inspect(path: &std::path::Path) -> io::Result<CheckpointInfo> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    Ok(read_raw(&mut f)?.info)
}

impl Checkpoint {
    /// The immutable serving view of this checkpoint.
    pub fn into_snapshot(self) -> ModelSnapshot {
        match self {
            Checkpoint::Sgd(s) => {
                let trained = s.steps();
                let digest =
                    config_digest(&sgd_cfg_text(&s), s.w.len() as u64, 0);
                ModelSnapshot::central(s.w, trained, digest)
            }
            Checkpoint::Coordinator(c) => c.snapshot(),
        }
    }

    /// Predict without consuming the checkpoint.
    pub fn predict(&self, x: &[crate::linalg::SparseFeat]) -> f64 {
        match self {
            Checkpoint::Sgd(s) => s.predict(x),
            Checkpoint::Coordinator(c) => c.predict(x),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Checkpoint::Sgd(s) => s.w.len(),
            Checkpoint::Coordinator(c) => c.dim(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UpdateRule;
    use crate::data::synth::{RcvLikeGen, SynthConfig};
    use crate::topology::Topology;

    fn trained_sgd() -> Sgd {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 500,
            features: 200,
            density: 10,
            hash_bits: 10,
            ..Default::default()
        })
        .generate();
        let mut s = Sgd::new(
            ds.dim,
            Loss::Logistic,
            LrSchedule::inv_sqrt(2.0, 10.0),
        );
        for inst in ds.iter() {
            s.learn(&inst.features, inst.label);
        }
        s
    }

    #[test]
    fn sgd_roundtrip_bit_identical() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Sgd(s) => s,
            _ => panic!("wrong kind"),
        };
        assert_eq!(back.w, s.w);
        assert_eq!(back.steps(), s.steps());
        assert_eq!(back.loss, s.loss);
        assert_eq!(back.lr, s.lr);
    }

    #[test]
    fn tree_roundtrip_identical_predictions() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 1_000,
            features: 300,
            density: 12,
            hash_bits: 11,
            ..Default::default()
        })
        .generate();
        let cfg = RunConfig {
            topology: Topology::TwoLayer { shards: 4 },
            rule: UpdateRule::Backprop { multiplier: 2.0 },
            loss: Loss::Logistic,
            lr: LrSchedule::inv_sqrt(2.0, 1.0),
            clip01: false,
            tau: 32,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, ds.dim);
        c.train(&ds);
        let mut buf = Vec::new();
        write_coordinator(&c, &mut buf).unwrap();
        let back = match read(&mut buf.as_slice()).unwrap() {
            Checkpoint::Coordinator(c) => c,
            _ => panic!("wrong kind"),
        };
        for inst in ds.iter().take(100) {
            let a = c.predict(&inst.features);
            let b = back.predict(&inst.features);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(back.trained_instances(), c.trained_instances());
    }

    #[test]
    fn central_roundtrip_identical_predictions() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 800,
            features: 200,
            density: 10,
            hash_bits: 10,
            ..Default::default()
        })
        .generate();
        let cfg = RunConfig {
            rule: UpdateRule::Minibatch { batch: 64 },
            loss: Loss::Logistic,
            clip01: false,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg, ds.dim);
        c.train(&ds);
        let mut buf = Vec::new();
        write_coordinator(&c, &mut buf).unwrap();
        let back = read(&mut buf.as_slice()).unwrap();
        for inst in ds.iter().take(50) {
            assert_eq!(
                c.predict(&inst.features).to_bits(),
                back.predict(&inst.features).to_bits()
            );
        }
    }

    #[test]
    fn truncation_errors_cleanly() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        for cut in [0, 3, 8, 31, 32, 40, buf.len() - 1] {
            let err = read(&mut &buf[..cut]).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "cut {cut}: {err}");
        }
    }

    #[test]
    fn corruption_detected_by_checksum() {
        let s = trained_sgd();
        let mut buf = Vec::new();
        write_sgd(&s, &mut buf).unwrap();
        // flip one byte deep in the weight payload
        let idx = buf.len() - 5;
        buf[idx] ^= 0x40;
        let err = read(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn inspect_reports_meta() {
        let s = trained_sgd();
        let dir = std::env::temp_dir().join("pol_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.polz");
        save_sgd(&s, &path).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.kind_name(), "sgd");
        assert_eq!(info.dim, s.w.len() as u64);
        assert_eq!(info.tables, 1);
        assert_eq!(info.total_params, s.w.len() as u64);
        assert!(info.config_text.contains("loss = logistic"));
        std::fs::remove_file(&path).ok();
    }
}
