//! Synthetic dataset generators standing in for the paper's data
//! (DESIGN.md §3 documents each substitution).
//!
//! * [`RcvLikeGen`] — RCV1-shaped sparse text-classification stream
//!   (Table 0.1 row 1: 780K × 23K).
//! * [`WebspamLikeGen`] — webspam-shaped denser stream with correlated
//!   feature blocks (Table 0.1 row 2: 300K × 50K).
//! * [`AdDisplayGen`] — the §0.5.3 ad-display task: namespaced
//!   (user, ad, page) features, logistic click model, pairwise training.
//! * [`AdversarialDupGen`] — the §0.4 adversarial duplicate-τ stream that
//!   saturates Theorem 1's lower bound.
//! * [`prop3`]/[`prop4`] — the exact 4-point distributions of
//!   Propositions 3 and 4.

/// Logged ad-display events and the pairwise set built from them.
pub mod ad_display;
/// Proposition 3's exact 4-point distribution.
pub mod prop3;
/// Proposition 4's exact 4-point distribution.
pub mod prop4;

pub use ad_display::AdDisplayGen;

use crate::data::Dataset;

/// Shared knobs for the stream generators.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Number of instances to generate.
    pub instances: usize,
    /// Nominal (pre-hash) vocabulary size.
    pub features: usize,
    /// Mean non-zero features per instance.
    pub density: usize,
    /// Label-flip noise probability.
    pub noise: f64,
    /// Hash bits for the weight table (dataset `dim` = 2^bits).
    pub hash_bits: u32,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            instances: 10_000,
            features: 23_000,
            density: 75,
            noise: 0.05,
            hash_bits: 18,
            seed: 42,
        }
    }
}

impl SynthConfig {
    /// Paper-scale RCV1 shape (Table 0.1): 780K × 23K.
    pub fn rcv1_full() -> Self {
        SynthConfig { instances: 780_000, features: 23_000, ..Default::default() }
    }

    /// Paper-scale webspam shape (Table 0.1): 300K × 50K.
    pub fn webspam_full() -> Self {
        SynthConfig {
            instances: 300_000,
            features: 50_000,
            density: 150,
            ..Default::default()
        }
    }
}

/// RCV1-like generator: Zipf-distributed token draws (power-law document
/// frequencies), TF-normalized values, labels from a planted sparse
/// hyperplane over the vocabulary plus flip noise. Labels ∈ {−1, +1}.
///
/// This is the eager wrapper over the streaming
/// [`crate::stream::RcvLikeSource`] (the primary implementation):
/// `generate()` materializes the identical stream, so in-memory and
/// streamed training see bit-identical data.
pub struct RcvLikeGen {
    /// Generation parameters.
    pub config: SynthConfig,
}

impl RcvLikeGen {
    /// A generator with `config`.
    pub fn new(config: SynthConfig) -> Self {
        RcvLikeGen { config }
    }

    /// Generate the dataset deterministically from the seed.
    pub fn generate(&self) -> Dataset {
        let mut src = crate::stream::RcvLikeSource::new(self.config.clone());
        crate::stream::read_all(&mut src)
            // pol-lint: allow(L001, "in-memory generator, no I/O error path")
            .expect("synthetic sources cannot fail")
    }
}

/// Webspam-like generator: features organized in correlated blocks —
/// within a block, feature values share a latent factor; the label
/// depends on *sums across blocks*, so tree-local training (which only
/// sees scalar summaries of cross-shard correlation, §0.5.2) is
/// systematically weaker than global rules. Denser than RCV1-like.
/// Labels ∈ {−1, +1}.
pub struct WebspamLikeGen {
    /// Generation parameters.
    pub config: SynthConfig,
    /// Number of correlated blocks.
    pub blocks: usize,
    /// Within-block correlation strength in [0,1].
    pub rho: f64,
}

impl WebspamLikeGen {
    /// A generator with `config`.
    pub fn new(config: SynthConfig) -> Self {
        WebspamLikeGen { config, blocks: 32, rho: 0.7 }
    }

    /// Materialize via the streaming
    /// [`crate::stream::WebspamLikeSource`] (the primary
    /// implementation), so in-memory and streamed training see
    /// bit-identical data.
    pub fn generate(&self) -> Dataset {
        let mut src = crate::stream::WebspamLikeSource::with_blocks(
            self.config.clone(),
            self.blocks,
            self.rho,
        );
        crate::stream::read_all(&mut src)
            // pol-lint: allow(L001, "in-memory generator, no I/O error path")
            .expect("synthetic sources cannot fail")
    }
}

/// §0.4 adversarial stream: each fresh IID instance is repeated τ times
/// consecutively, so an algorithm with update delay τ cannot use any
/// information about an instance while it is still being shown — this is
/// the construction behind Theorem 1's √τ slowdown.
pub struct AdversarialDupGen {
    /// Base generation parameters.
    pub base: SynthConfig,
    /// Duplication run length (matches the feedback delay under test).
    pub tau: usize,
}

impl AdversarialDupGen {
    /// A generator duplicating examples in runs of `tau`.
    pub fn new(base: SynthConfig, tau: usize) -> Self {
        AdversarialDupGen { base, tau: tau.max(1) }
    }

    /// Generate the dataset deterministically from the seed.
    pub fn generate(&self) -> Dataset {
        let uniques = (self.base.instances / self.tau).max(1);
        let inner = RcvLikeGen::new(SynthConfig {
            instances: uniques,
            ..self.base.clone()
        })
        .generate();
        let mut ds = Dataset::new(format!("adversarial-dup{}", self.tau), inner.dim);
        let mut tag = 0u64;
        for inst in &inner.instances {
            for _ in 0..self.tau {
                let mut i = inst.clone();
                i.tag = tag;
                tag += 1;
                ds.instances.push(i);
            }
        }
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynthConfig {
        SynthConfig { instances: 2_000, features: 500, density: 20, ..Default::default() }
    }

    #[test]
    fn rcv_like_shape() {
        let ds = RcvLikeGen::new(small()).generate();
        assert_eq!(ds.len(), 2_000);
        assert!(ds.mean_features() > 5.0 && ds.mean_features() < 40.0);
        for i in ds.iter().take(50) {
            assert!(i.label == 1.0 || i.label == -1.0);
            assert!(!i.features.is_empty());
        }
    }

    #[test]
    fn rcv_like_deterministic() {
        let a = RcvLikeGen::new(small()).generate();
        let b = RcvLikeGen::new(small()).generate();
        assert_eq!(a.instances[17], b.instances[17]);
    }

    #[test]
    fn rcv_like_learnable() {
        // a plain SGD pass should beat chance comfortably on sep+noise data
        let ds = RcvLikeGen::new(SynthConfig { instances: 6_000, ..small() }).generate();
        let mut w = vec![0.0f32; ds.dim];
        let mut correct = 0;
        for (t, inst) in ds.iter().enumerate() {
            let yhat = crate::linalg::sparse_dot(&w, &inst.features);
            if (yhat >= 0.0) == (inst.label > 0.0) && t >= 5000 {
                correct += 1;
            }
            let g = crate::loss::Loss::Logistic.dloss(yhat, inst.label);
            let eta = 4.0 / ((t + 1) as f64).sqrt();
            crate::linalg::sparse_saxpy(&mut w, -eta * g, &inst.features);
        }
        let acc = correct as f64 / 1000.0;
        assert!(acc > 0.7, "acc {acc}");
    }

    #[test]
    fn webspam_like_shape() {
        let ds = WebspamLikeGen::new(small()).generate();
        assert_eq!(ds.len(), 2_000);
        let balance: f64 =
            ds.iter().map(|i| if i.label > 0.0 { 1.0 } else { 0.0 }).sum::<f64>()
                / ds.len() as f64;
        assert!(balance > 0.2 && balance < 0.8, "balance {balance}");
    }

    #[test]
    fn adversarial_duplicates_consecutive() {
        let gen = AdversarialDupGen::new(small(), 8);
        let ds = gen.generate();
        for chunk in ds.instances.chunks(8).take(10) {
            for w in chunk.windows(2) {
                assert_eq!(w[0].features, w[1].features);
                assert_eq!(w[0].label, w[1].label);
            }
        }
        // tags remain unique
        assert_ne!(ds.instances[0].tag, ds.instances[1].tag);
    }

    #[test]
    fn table01_shapes() {
        // Table 0.1 sanity: the full-shape configs carry the paper's
        // dimensions (not generated here — too big for unit tests).
        let r = SynthConfig::rcv1_full();
        assert_eq!((r.instances, r.features), (780_000, 23_000));
        let w = SynthConfig::webspam_full();
        assert_eq!((w.instances, w.features), (300_000, 50_000));
    }
}
