//! Synthetic stand-in for the paper's proprietary ad-display dataset
//! (§0.5.3): "derive a good policy for choosing an ad given user, ad, and
//! page display features ... via pairwise training concerning which of
//! two ads was clicked on and element-wise evaluation with an offline
//! policy evaluator".
//!
//! Ground truth: a logistic click model over (user, ad, page) features
//! plus user×ad interaction terms. Each *display event* shows two
//! candidate ads on a page to a user; the logged click gives a pairwise
//! training instance (features of the clicked ad minus features of the
//! other, label 1/0 per the paper's squared-loss [0,1] convention), and
//! an element-wise (ad, context, click) log for the offline policy
//! evaluator ([`crate::eval::policy`]).

use crate::data::instance::Instance;
use crate::data::Dataset;
use crate::hashing::FeatureHasher;
use crate::linalg::SparseFeat;
use crate::rng::Rng;

/// One logged display event: the context, the two candidate ads, which
/// was shown in the favoured slot, and whether it was clicked.
#[derive(Clone, Debug)]
pub struct DisplayEvent {
    /// Hashed features of (user, page) context joined with each ad.
    pub ad_a: Vec<SparseFeat>,
    /// Hashed features of the (user, page) context joined with ad B.
    pub ad_b: Vec<SparseFeat>,
    /// True click-through probabilities (hidden from learners; used by
    /// the policy evaluator's ground-truth mode).
    pub ctr_a: f64,
    /// True click-through probability of ad B.
    pub ctr_b: f64,
    /// Which ad the logging policy displayed (0 = a, 1 = b).
    pub shown: u8,
    /// Click outcome for the shown ad.
    pub clicked: bool,
}

#[derive(Clone, Debug)]
/// Shape of the synthetic ad-display stream.
pub struct AdDisplayConfig {
    /// Number of display events.
    pub events: usize,
    /// Distinct users.
    pub users: usize,
    /// Distinct ads.
    pub ads: usize,
    /// Distinct pages.
    pub pages: usize,
    /// Features per namespace draw.
    pub user_feats: usize,
    /// Features per ad.
    pub ad_feats: usize,
    /// Features per page.
    pub page_feats: usize,
    /// Hash bits for the feature space.
    pub hash_bits: u32,
    /// RNG seed.
    pub seed: u64,
}

impl Default for AdDisplayConfig {
    fn default() -> Self {
        AdDisplayConfig {
            events: 20_000,
            users: 2_000,
            ads: 100,
            pages: 500,
            user_feats: 8,
            ad_feats: 6,
            page_feats: 4,
            hash_bits: 18,
            seed: 7,
        }
    }
}

/// Generator for the ad-display corpus.
pub struct AdDisplayGen {
    /// Generation parameters.
    pub config: AdDisplayConfig,
}

/// The generated corpus: pairwise training set + event log for policy
/// evaluation.
pub struct AdDisplayCorpus {
    /// Pairwise-preference training set.
    pub pairwise: Dataset,
    /// The raw display events.
    pub events: Vec<DisplayEvent>,
    /// Hashed feature dimension.
    pub dim: usize,
}

impl AdDisplayGen {
    /// A generator with `config`.
    pub fn new(config: AdDisplayConfig) -> Self {
        AdDisplayGen { config }
    }

    /// A small corpus sized for tests.
    pub fn default_small() -> Self {
        AdDisplayGen { config: AdDisplayConfig::default() }
    }

    /// Generate the corpus deterministically from the seed.
    pub fn generate(&self) -> AdDisplayCorpus {
        let c = &self.config;
        let mut rng = Rng::new(c.seed);
        let hasher = FeatureHasher::new(c.hash_bits);
        let dim = hasher.table_size();
        let ns_user = hasher.namespace_seed(b"user");
        let ns_ad = hasher.namespace_seed(b"ad");
        let ns_page = hasher.namespace_seed(b"page");

        // hidden logistic click model over the hashed space: weights for
        // base features and for user×ad crosses
        let mut w_true = vec![0.0f64; dim];
        let mut wrng = rng.fork(1);
        for wt in w_true.iter_mut() {
            *wt = wrng.normal() * 0.45;
        }

        // entity feature ids (each user/ad/page is a bag of ids)
        let mut ent_rng = rng.fork(2);
        let user_ids: Vec<Vec<u64>> = (0..c.users)
            .map(|u| {
                (0..c.user_feats)
                    .map(|_| u as u64 * 131 + ent_rng.below(1 << 20))
                    .collect()
            })
            .collect();
        let ad_ids: Vec<Vec<u64>> = (0..c.ads)
            .map(|a| {
                (0..c.ad_feats)
                    .map(|_| a as u64 * 257 + ent_rng.below(1 << 20))
                    .collect()
            })
            .collect();
        let page_ids: Vec<Vec<u64>> = (0..c.pages)
            .map(|p| {
                (0..c.page_feats)
                    .map(|_| p as u64 * 101 + ent_rng.below(1 << 20))
                    .collect()
            })
            .collect();

        let featurize = |user: usize, ad: usize, page: usize| -> Vec<SparseFeat> {
            let mut f: Vec<SparseFeat> = Vec::with_capacity(
                c.user_feats + c.ad_feats + c.page_feats + c.user_feats * c.ad_feats,
            );
            let mut u_idx = Vec::with_capacity(c.user_feats);
            for &id in &user_ids[user] {
                let (i, s) = hasher.hash_id(ns_user, id);
                u_idx.push(i);
                f.push((i, s));
            }
            let mut a_idx = Vec::with_capacity(c.ad_feats);
            for &id in &ad_ids[ad] {
                let (i, s) = hasher.hash_id(ns_ad, id);
                a_idx.push(i);
                f.push((i, s));
            }
            for &id in &page_ids[page] {
                let (i, s) = hasher.hash_id(ns_page, id);
                f.push((i, s));
            }
            // §0.2 outer-product features, generated on the fly. Down-
            // weighted: interaction effects are real but secondary, so
            // the (rarely repeating) cross slots don't drown the
            // learnable base-feature signal in the ground-truth CTR.
            for &ui in &u_idx {
                for &ai in &a_idx {
                    let (idx, sign) = hasher.hash_pair(ui, ai);
                    f.push((idx, sign * 0.25));
                }
            }
            f
        };

        let ctr = |f: &[SparseFeat]| -> f64 {
            let z: f64 =
                f.iter().map(|&(i, v)| w_true[i as usize] * v as f64).sum();
            1.0 / (1.0 + (-(z - 1.0)).exp()) // shift: realistic low CTR
        };

        let mut pairwise = Dataset::new("ad-display-pairwise", dim);
        pairwise.instances.reserve(c.events);
        let mut events = Vec::with_capacity(c.events);
        for t in 0..c.events {
            let user = rng.below(c.users as u64) as usize;
            let page = rng.below(c.pages as u64) as usize;
            let a = rng.below(c.ads as u64) as usize;
            let mut b = rng.below(c.ads as u64) as usize;
            if b == a {
                b = (b + 1) % c.ads;
            }
            let fa = featurize(user, a, page);
            let fb = featurize(user, b, page);
            let (pa, pb) = (ctr(&fa), ctr(&fb));
            // logging policy: uniform random over the two slots, so the
            // offline policy evaluator is unbiased (Langford et al. 2008)
            let shown = if rng.bernoulli(0.5) { 0u8 } else { 1u8 };
            let p_shown = if shown == 0 { pa } else { pb };
            let clicked = rng.bernoulli(p_shown);

            // pairwise instance: difference features, label = did the
            // *shown* ad get clicked, oriented so label 1 means "ad A
            // preferred" (paper trains pairwise, evaluates element-wise)
            let mut features = Vec::with_capacity(fa.len() + fb.len() + 1);
            features.extend(fa.iter().map(|&(i, v)| (i, v)));
            features.extend(fb.iter().map(|&(i, v)| (i, -v)));
            // constant feature: difference features have zero mean, so
            // the 0/1-label offset needs an explicit bias slot
            features.push(hasher.hash(0, b"__bias__"));
            let label = match (shown, clicked) {
                (0, true) | (1, false) => 1.0,
                _ => 0.0,
            };
            pairwise.instances.push(Instance {
                label,
                weight: 1.0,
                features,
                tag: t as u64,
            });
            events.push(DisplayEvent {
                ad_a: fa,
                ad_b: fb,
                ctr_a: pa,
                ctr_b: pb,
                shown,
                clicked,
            });
        }
        AdDisplayCorpus { pairwise, events, dim }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> AdDisplayConfig {
        AdDisplayConfig { events: 2_000, ..Default::default() }
    }

    #[test]
    fn corpus_shapes() {
        let c = AdDisplayGen::new(small()).generate();
        assert_eq!(c.pairwise.len(), 2_000);
        assert_eq!(c.events.len(), 2_000);
        for inst in c.pairwise.iter().take(20) {
            assert!(inst.label == 0.0 || inst.label == 1.0);
            // base + cross features for both ads
            assert!(inst.features.len() > 20);
        }
    }

    #[test]
    fn deterministic() {
        let a = AdDisplayGen::new(small()).generate();
        let b = AdDisplayGen::new(small()).generate();
        assert_eq!(a.pairwise.instances[11], b.pairwise.instances[11]);
        assert_eq!(a.events[11].clicked, b.events[11].clicked);
    }

    #[test]
    fn ctrs_are_probabilities() {
        let c = AdDisplayGen::new(small()).generate();
        for e in &c.events {
            assert!(e.ctr_a > 0.0 && e.ctr_a < 1.0);
            assert!(e.ctr_b > 0.0 && e.ctr_b < 1.0);
        }
    }

    #[test]
    fn clicks_correlate_with_ctr() {
        let c = AdDisplayGen::new(AdDisplayConfig { events: 20_000, ..small() })
            .generate();
        let (mut hi, mut hi_n, mut lo, mut lo_n) = (0.0, 0, 0.0, 0);
        for e in &c.events {
            let p = if e.shown == 0 { e.ctr_a } else { e.ctr_b };
            if p > 0.5 {
                hi += e.clicked as u8 as f64;
                hi_n += 1;
            } else {
                lo += e.clicked as u8 as f64;
                lo_n += 1;
            }
        }
        if hi_n > 100 && lo_n > 100 {
            assert!(hi / hi_n as f64 > lo / lo_n as f64);
        }
    }

    #[test]
    fn pairwise_learnable() {
        // clicks are Bernoulli, so the oracle MSE is ~0.226 and the best
        // constant predictor ~0.250; a plain squared-loss learner must
        // land clearly between the two on the last quarter of the stream
        let n = 20_000;
        let c = AdDisplayGen::new(AdDisplayConfig { events: n, ..small() })
            .generate();
        let mut w = vec![0.0f32; c.dim];
        let mut pv = crate::metrics::ProgressiveValidator::new();
        for (t, inst) in c.pairwise.iter().enumerate() {
            let yhat = crate::linalg::sparse_dot(&w, &inst.features);
            if t > 3 * n / 4 {
                pv.observe(yhat, inst.label);
            }
            let g = crate::loss::Loss::Squared.dloss(yhat, inst.label);
            // stability: ||x||^2 ~ 40 after cross down-weighting
            crate::linalg::sparse_saxpy(&mut w, -0.005 * g, &inst.features);
        }
        assert!(pv.mean_squared() < 0.246, "mse {}", pv.mean_squared());
    }
}
