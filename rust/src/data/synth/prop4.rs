//! Proposition 4's exact 4-point distribution: *neither* the binary tree
//! nor Naïve Bayes can represent the least-squares predictor.
//!
//! | point | x1 | x2 | x3 |  y |
//! |-------|----|----|----|----|
//! | 1     | +1 | −1 | −1 | −1 |
//! | 2     | −1 | +1 | −1 | −1 |
//! | 3     | +1 | +1 | −1 | +1 |
//! | 4     | +1 | +1 | −1 | +1 |
//!
//! The optimal linear predictor is w* = (1, 1, 1) with zero error; x3 is
//! *individually* uncorrelated with y, so any local rule assigns it zero
//! weight and incurs squared error ≥ 1/2. §0.6 fixes this with global
//! updates — the delayed-backprop experiments use exactly this structure.

/// The four (x, y) points, uniformly distributed.
pub const POINTS: [([f64; 3], f64); 4] = [
    ([1.0, -1.0, -1.0], -1.0),
    ([-1.0, 1.0, -1.0], -1.0),
    ([1.0, 1.0, -1.0], 1.0),
    ([1.0, 1.0, -1.0], 1.0),
];

/// The all-ones optimal least-squares predictor the paper states.
pub const OPTIMAL_W: [f64; 3] = [1.0, 1.0, 1.0];

/// Lower bound on the squared error of any predictor with w3 = 0.
pub const LOCAL_MSE_LOWER_BOUND: f64 = 0.5;

/// Feature dimension of the construction.
pub const DIM: usize = 3;

/// As a cyclically-repeating dataset of `n` instances.
pub fn dataset(n: usize) -> crate::data::Dataset {
    let mut ds = crate::data::Dataset::new("prop4", DIM);
    for t in 0..n {
        let (x, y) = POINTS[t % 4];
        ds.instances.push(crate::data::instance::Instance {
            label: y,
            weight: 1.0,
            features: x
                .iter()
                .enumerate()
                .map(|(i, &v)| (i as u32, v as f32))
                .collect(),
            tag: t as u64,
        });
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_w_has_zero_error() {
        for (x, y) in POINTS {
            let p: f64 = x.iter().zip(&OPTIMAL_W).map(|(a, b)| a * b).sum();
            assert!((p - y).abs() < 1e-12);
        }
    }

    #[test]
    fn x3_uncorrelated_with_y() {
        let b3: f64 = POINTS.iter().map(|(x, y)| x[2] * y).sum();
        assert_eq!(b3, 0.0);
    }

    #[test]
    fn any_zero_w3_predictor_mse_at_least_half() {
        // brute-force grid over (w1, w2): min MSE with w3 = 0 is 1/2
        let mut best = f64::INFINITY;
        let steps = 200;
        for i in 0..=steps {
            for j in 0..=steps {
                let w1 = -2.0 + 4.0 * i as f64 / steps as f64;
                let w2 = -2.0 + 4.0 * j as f64 / steps as f64;
                let mse: f64 = POINTS
                    .iter()
                    .map(|(x, y)| {
                        let p = w1 * x[0] + w2 * x[1];
                        (p - y) * (p - y)
                    })
                    .sum::<f64>()
                    / 4.0;
                best = best.min(mse);
            }
        }
        assert!(best >= LOCAL_MSE_LOWER_BOUND - 1e-9, "best {best}");
    }
}
