//! Proposition 3's exact 4-point distribution: the binary-tree
//! architecture can represent the least-squares predictor but Naïve
//! Bayes cannot.
//!
//! | point | x1 | x2 | x3  |  y |
//! |-------|----|----|-----|----|
//! | 1     | +1 | +1 | −1/2| +1 |
//! | 2     | +1 | −1 | −1  | −1 |
//! | 3     | −1 | −1 | −1/2| +1 |
//! | 4     | −1 | +1 | +1  | +1 |
//!
//! Paper: Naïve Bayes yields w = (−1/2, 1/2, 2/5) with MSE 0.8; the tree
//! learns the extra layer weights, ultimately (−3/2, 3/2, −2) with zero
//! MSE. Our tests in `rust/tests/test_propositions.rs` verify both
//! numbers exactly.

/// The four (x, y) points, uniformly distributed.
pub const POINTS: [([f64; 3], f64); 4] = [
    ([1.0, 1.0, -0.5], 1.0),
    ([1.0, -1.0, -1.0], -1.0),
    ([-1.0, -1.0, -0.5], 1.0),
    ([-1.0, 1.0, 1.0], 1.0),
];

/// Naïve Bayes weights the paper states: (−1/2, 1/2, 2/5).
pub const NAIVE_BAYES_W: [f64; 3] = [-0.5, 0.5, 0.4];

/// Naïve Bayes MSE the paper states.
pub const NAIVE_BAYES_MSE: f64 = 0.8;

/// Final overall weight vector of the tree architecture: (−3/2, 3/2, −2).
pub const TREE_W: [f64; 3] = [-1.5, 1.5, -2.0];

/// Dimension of the feature space.
pub const DIM: usize = 3;

/// As a cyclically-repeating dataset of `n` instances (dense features at
/// indices 0..3).
pub fn dataset(n: usize) -> crate::data::Dataset {
    let mut ds = crate::data::Dataset::new("prop3", DIM);
    for t in 0..n {
        let (x, y) = POINTS[t % 4];
        ds.instances.push(crate::data::instance::Instance {
            label: y,
            weight: 1.0,
            features: x
                .iter()
                .enumerate()
                .map(|(i, &v)| (i as u32, v as f32))
                .collect(),
            tag: t as u64,
        });
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_bayes_weights_are_per_feature_least_squares() {
        // w_i^(0) = b_i / Σ_ii  (paper §0.5.2)
        for i in 0..3 {
            let b: f64 = POINTS.iter().map(|(x, y)| x[i] * y).sum::<f64>() / 4.0;
            let s: f64 = POINTS.iter().map(|(x, _)| x[i] * x[i]).sum::<f64>() / 4.0;
            assert!(
                (b / s - NAIVE_BAYES_W[i]).abs() < 1e-12,
                "feature {i}: {} vs {}",
                b / s,
                NAIVE_BAYES_W[i]
            );
        }
    }

    #[test]
    fn naive_bayes_mse_is_point_eight() {
        let mse: f64 = POINTS
            .iter()
            .map(|(x, y)| {
                let p: f64 = x.iter().zip(&NAIVE_BAYES_W).map(|(a, b)| a * b).sum();
                (p - y) * (p - y)
            })
            .sum::<f64>()
            / 4.0;
        assert!((mse - NAIVE_BAYES_MSE).abs() < 1e-12, "mse {mse}");
    }

    #[test]
    fn tree_weights_have_zero_mse() {
        let mse: f64 = POINTS
            .iter()
            .map(|(x, y)| {
                let p: f64 = x.iter().zip(&TREE_W).map(|(a, b)| a * b).sum();
                (p - y) * (p - y)
            })
            .sum::<f64>()
            / 4.0;
        assert!(mse < 1e-12, "mse {mse}");
    }

    #[test]
    fn dataset_cycles() {
        let ds = dataset(8);
        assert_eq!(ds.instances[0].label, ds.instances[4].label);
        assert_eq!(ds.instances[1].features, ds.instances[5].features);
    }
}
