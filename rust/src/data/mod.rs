//! Data formats: instance type, VW-style text parser, binary cache,
//! and synthetic dataset generators (the paper's datasets are either
//! proprietary or hardware-gated; DESIGN.md §3 documents the
//! substitutions).
//!
//! **Ingestion happens in [`crate::stream`]**: every trainer consumes
//! an [`crate::stream::InstanceSource`] (file, cache, generator, or
//! in-memory dataset) through a [`crate::stream::Pipeline`] — a
//! background parsing thread feeding a bounded pool of recycled
//! instance batches, the paper's §0.5.1 asynchronous-parse design.
//! [`Dataset`] remains the *materialized* form: what you get from
//! [`crate::stream::read_all`], what `split_test` carves held-out sets
//! from, and what [`crate::model::Session::train`] adapts back onto
//! the streaming path via [`crate::stream::DatasetSource`]. It is no
//! longer the only way data reaches a learner — streams larger than
//! memory train at pool-bounded RSS with bit-identical weights.

/// Binary dataset cache.
pub mod cache;
/// The sparse instance type.
pub mod instance;
/// VW-style text parsing.
pub mod parser;
/// Synthetic dataset generators.
pub mod synth;

use instance::Instance;

/// An in-memory dataset plus the metadata learners need.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Dataset name.
    pub name: String,
    /// Hashed feature-space size (weight-table length learners allocate).
    pub dim: usize,
    /// The instances, in stream order.
    pub instances: Vec<Instance>,
}

impl Dataset {
    /// An empty dataset named `name` over `dim` features.
    pub fn new(name: impl Into<String>, dim: usize) -> Self {
        Dataset { name: name.into(), dim, instances: Vec::new() }
    }

    /// Number of instances.
    pub fn len(&self) -> usize {
        self.instances.len()
    }

    /// Whether there are no instances.
    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Iterate the instances in order.
    pub fn iter(&self) -> std::slice::Iter<'_, Instance> {
        self.instances.iter()
    }

    /// Split off the last `frac` fraction as a test set (time-ordered
    /// split — the natural choice for online data).
    pub fn split_test(mut self, frac: f64) -> (Dataset, Dataset) {
        let n = self.instances.len();
        let cut = ((n as f64) * (1.0 - frac)).round() as usize;
        let test_insts = self.instances.split_off(cut.min(n));
        let test = Dataset {
            name: format!("{}-test", self.name),
            dim: self.dim,
            instances: test_insts,
        };
        self.name = format!("{}-train", self.name);
        (self, test)
    }

    /// Total non-zero feature count (the paper sizes datasets this way:
    /// "60M total (non-unique) features").
    pub fn total_features(&self) -> u64 {
        self.instances.iter().map(|i| i.features.len() as u64).sum()
    }

    /// Mean features per instance.
    pub fn mean_features(&self) -> f64 {
        if self.instances.is_empty() {
            0.0
        } else {
            self.total_features() as f64 / self.len() as f64
        }
    }

    /// Deterministically shuffle instance order.
    pub fn shuffle(&mut self, rng: &mut crate::rng::Rng) {
        rng.shuffle(&mut self.instances);
    }

    /// Repeat the dataset for multi-pass training (Fig 0.6 rows 3–4).
    pub fn passes(&self, n: usize) -> impl Iterator<Item = &Instance> {
        std::iter::repeat_with(move || self.instances.iter())
            .take(n)
            .flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::instance::Instance;

    fn tiny() -> Dataset {
        let mut ds = Dataset::new("t", 8);
        for i in 0..10 {
            ds.instances.push(Instance {
                label: (i % 2) as f64,
                weight: 1.0,
                features: vec![(i as u32 % 8, 1.0)],
                tag: i as u64,
            });
        }
        ds
    }

    #[test]
    fn split_test_sizes() {
        let (tr, te) = tiny().split_test(0.3);
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
        assert_eq!(te.instances[0].tag, 7);
    }

    #[test]
    fn passes_iterates_n_times() {
        let ds = tiny();
        assert_eq!(ds.passes(3).count(), 30);
    }

    #[test]
    fn feature_counts() {
        let ds = tiny();
        assert_eq!(ds.total_features(), 10);
        assert!((ds.mean_features() - 1.0).abs() < 1e-12);
    }
}
