//! Binary cache format for parsed datasets.
//!
//! The paper (§0.2) credits VW's speed partly to "a good choice of cache
//! format": parse the text once, then stream a compact binary encoding
//! on every subsequent pass. Ours is a simple length-prefixed record
//! stream with varint-delta feature indices — the same idea.
//!
//! Layout:
//! ```text
//! magic "POLC" | u32 version | u64 dim | u64 count
//! per record: f64 label | f32 weight | u64 tag | u32 nfeat
//!             nfeat × (varint delta-index, f32 value)
//! ```

use std::io::{self, Read, Write};

use crate::data::instance::Instance;
use crate::data::Dataset;

const MAGIC: &[u8; 4] = b"POLC";
const VERSION: u32 = 1;

fn write_varint(mut v: u64, out: &mut impl Write) -> io::Result<()> {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.write_all(&[b])?;
            return Ok(());
        }
        out.write_all(&[b | 0x80])?;
    }
}

fn read_varint(inp: &mut impl Read) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0;
    loop {
        let mut b = [0u8; 1];
        inp.read_exact(&mut b)?;
        v |= ((b[0] & 0x7f) as u64) << shift;
        if b[0] & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "varint"));
        }
    }
}

/// Serialize a dataset to the cache format.
pub fn write_cache(ds: &Dataset, out: &mut impl Write) -> io::Result<()> {
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&(ds.dim as u64).to_le_bytes())?;
    out.write_all(&(ds.len() as u64).to_le_bytes())?;
    let mut sorted: Vec<(u32, f32)> = Vec::new();
    for inst in &ds.instances {
        out.write_all(&inst.label.to_le_bytes())?;
        out.write_all(&inst.weight.to_le_bytes())?;
        out.write_all(&inst.tag.to_le_bytes())?;
        out.write_all(&(inst.features.len() as u32).to_le_bytes())?;
        sorted.clear();
        sorted.extend_from_slice(&inst.features);
        sorted.sort_unstable_by_key(|&(i, _)| i);
        let mut prev = 0u64;
        for &(i, v) in &sorted {
            write_varint(i as u64 - prev, out)?;
            out.write_all(&v.to_le_bytes())?;
            prev = i as u64;
        }
    }
    Ok(())
}

/// Byte length of the fixed header ([`read_header`] consumes exactly
/// this many bytes) — where the first record begins.
pub const HEADER_LEN: u64 = 24;

/// Parsed cache header.
#[derive(Clone, Copy, Debug)]
pub struct CacheHeader {
    /// Hashed feature-space size the records index into.
    pub dim: usize,
    /// Number of records that follow.
    pub count: u64,
}

/// Read and validate the cache header (magic, version, dim, count).
pub fn read_header(inp: &mut impl Read) -> io::Result<CacheHeader> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    inp.read_exact(&mut u32b)?;
    if u32::from_le_bytes(u32b) != VERSION {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad version"));
    }
    inp.read_exact(&mut u64b)?;
    let dim = u64::from_le_bytes(u64b) as usize;
    inp.read_exact(&mut u64b)?;
    let count = u64::from_le_bytes(u64b);
    Ok(CacheHeader { dim, count })
}

/// Read one record into a reused instance (the streaming hot path:
/// feature capacity is retained across records). Truncated input is an
/// `UnexpectedEof` error.
pub fn read_record_into(
    inp: &mut impl Read,
    inst: &mut Instance,
) -> io::Result<()> {
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    let mut f32b = [0u8; 4];
    inp.read_exact(&mut u64b)?;
    inst.label = f64::from_le_bytes(u64b);
    inp.read_exact(&mut f32b)?;
    inst.weight = f32::from_le_bytes(f32b);
    inp.read_exact(&mut u64b)?;
    inst.tag = u64::from_le_bytes(u64b);
    inp.read_exact(&mut u32b)?;
    let nfeat = u32::from_le_bytes(u32b) as usize;
    inst.features.clear();
    inst.features.reserve(nfeat.min(1 << 20));
    let mut prev = 0u64;
    for _ in 0..nfeat {
        let delta = read_varint(inp)?;
        prev += delta;
        inp.read_exact(&mut f32b)?;
        inst.features.push((prev as u32, f32::from_le_bytes(f32b)));
    }
    Ok(())
}

/// Deserialize a cache stream.
pub fn read_cache(inp: &mut impl Read, name: &str) -> io::Result<Dataset> {
    let header = read_header(inp)?;
    let mut ds = Dataset::new(name, header.dim);
    ds.instances.reserve(header.count as usize);
    for _ in 0..header.count {
        let mut inst = Instance::new(0.0, Vec::new());
        read_record_into(inp, &mut inst)?;
        ds.instances.push(inst);
    }
    Ok(ds)
}

/// Write to / read from a file path.
pub fn save(ds: &Dataset, path: &std::path::Path) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    write_cache(ds, &mut f)
}

/// Load the cached dataset `name` from `path`.
pub fn load(path: &std::path::Path, name: &str) -> io::Result<Dataset> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    read_cache(&mut f, name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn make_ds(n: usize) -> Dataset {
        let mut rng = Rng::new(1);
        let mut ds = Dataset::new("c", 1 << 16);
        for t in 0..n {
            let k = 1 + rng.below(20) as usize;
            let features = (0..k)
                .map(|_| (rng.below(1 << 16) as u32, rng.normal() as f32))
                .collect();
            ds.instances.push(Instance {
                label: rng.below(2) as f64,
                weight: 1.0,
                features,
                tag: t as u64,
            });
        }
        ds
    }

    #[test]
    fn roundtrip_preserves_sorted_features() {
        let ds = make_ds(200);
        let mut buf = Vec::new();
        write_cache(&ds, &mut buf).unwrap();
        let back = read_cache(&mut buf.as_slice(), "c").unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim, ds.dim);
        for (a, b) in ds.instances.iter().zip(&back.instances) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.tag, b.tag);
            let mut fa = a.features.clone();
            fa.sort_unstable_by_key(|&(i, _)| i);
            assert_eq!(fa, b.features);
        }
    }

    #[test]
    fn cache_smaller_than_naive() {
        // delta-varint beats fixed u32 indices on sorted sparse rows
        let ds = make_ds(500);
        let mut buf = Vec::new();
        write_cache(&ds, &mut buf).unwrap();
        let naive: usize = ds
            .instances
            .iter()
            .map(|i| 8 + 4 + 8 + 4 + i.features.len() * 8)
            .sum();
        assert!(buf.len() < naive, "{} !< {}", buf.len(), naive);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = b"XXXX".to_vec();
        buf.extend_from_slice(&[0u8; 32]);
        assert!(read_cache(&mut buf.as_slice(), "x").is_err());
    }

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX / 2] {
            let mut buf = Vec::new();
            write_varint(v, &mut buf).unwrap();
            assert_eq!(read_varint(&mut buf.as_slice()).unwrap(), v);
        }
    }

    #[test]
    fn file_roundtrip() {
        let ds = make_ds(50);
        let dir = std::env::temp_dir().join("pol_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.polc");
        save(&ds, &path).unwrap();
        let back = load(&path, "t").unwrap();
        assert_eq!(back.len(), 50);
        std::fs::remove_file(&path).ok();
    }
}
