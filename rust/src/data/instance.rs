//! The labeled-instance type flowing through every learner and node.

use crate::linalg::SparseFeat;

/// A labeled, hashed, sparse instance.
///
/// `features` carry *hashed* indices into a `2^bits` weight table, values
/// already multiplied by the hashing sign. The label convention depends
/// on the loss: `[0,1]` for squared (click prediction), `{-1,+1}` for
/// logistic/hinge.
#[derive(Clone, Debug, PartialEq)]
pub struct Instance {
    /// Supervised label.
    pub label: f64,
    /// Importance weight (1.0 for all paper experiments).
    pub weight: f32,
    /// Sorted-by-index not required; duplicates allowed (they add).
    pub features: Vec<SparseFeat>,
    /// Stable id for delay bookkeeping and deterministic tracing.
    pub tag: u64,
}

impl Instance {
    /// An instance with `label` and sparse `features`.
    pub fn new(label: f64, features: Vec<SparseFeat>) -> Self {
        Instance { label, weight: 1.0, features, tag: 0 }
    }

    /// Attach an opaque tag (e.g. a source line number).
    pub fn with_tag(mut self, tag: u64) -> Self {
        self.tag = tag;
        self
    }

    /// Restrict to the features a shard owns (indices for which `keep`
    /// returns true) — Fig 0.4 step (b).
    pub fn project(&self, keep: impl Fn(u32) -> bool) -> Instance {
        Instance {
            label: self.label,
            weight: self.weight,
            features: self
                .features
                .iter()
                .copied()
                .filter(|&(i, _)| keep(i))
                .collect(),
            tag: self.tag,
        }
    }

    /// L2 norm of the feature vector.
    pub fn norm(&self) -> f64 {
        crate::linalg::sparse_norm_sq(&self.features).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn project_keeps_subset() {
        let inst = Instance::new(1.0, vec![(0, 1.0), (3, 2.0), (5, -1.0)]);
        let p = inst.project(|i| i >= 3);
        assert_eq!(p.features, vec![(3, 2.0), (5, -1.0)]);
        assert_eq!(p.label, 1.0);
        assert_eq!(p.tag, inst.tag);
    }

    #[test]
    fn norm_basic() {
        let inst = Instance::new(0.0, vec![(0, 3.0), (1, 4.0)]);
        assert!((inst.norm() - 5.0).abs() < 1e-12);
    }
}
