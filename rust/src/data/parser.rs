//! VW-style text-format parser.
//!
//! Grammar (subset of Vowpal Wabbit's input format, enough for real
//! datasets in that format):
//!
//! ```text
//! <label> [<importance>] ['<tag>] |<ns>[:<scale>] f[:v] f[:v] ... |<ns2> ...
//! ```
//!
//! Example: `1 0.5 'id42 |user age:0.31 premium |ad sports id77`
//!
//! Features are hashed with [`FeatureHasher`] per namespace. Quadratic
//! (outer-product) namespaces à la `-q ua` are generated on the fly —
//! the paper's §0.2 interaction features — via [`ParserConfig::quadratic`].

use crate::data::instance::Instance;
use crate::hashing::FeatureHasher;
use crate::linalg::SparseFeat;

#[derive(Clone, Debug, Default)]
/// Knobs for the VW-style text parser.
pub struct ParserConfig {
    /// Pairs of namespace initials to cross, e.g. `[('u','a')]` for
    /// VW's `-q ua` (user×ad outer-product features).
    pub quadratic: Vec<(char, char)>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
/// Why a line failed to parse.
pub enum ParseError {
    /// The line had no tokens.
    Empty,
    /// The label token did not parse.
    BadLabel(String),
    /// A feature value did not parse.
    BadValue(String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Empty => write!(f, "empty line"),
            ParseError::BadLabel(s) => write!(f, "bad label: {s}"),
            ParseError::BadValue(s) => write!(f, "bad feature value: {s}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// Parser for `label feat:val ...` text lines.
pub struct Parser {
    hasher: FeatureHasher,
    config: ParserConfig,
    line_no: u64,
}

impl Parser {
    /// A parser hashing features through `hasher`.
    pub fn new(hasher: FeatureHasher, config: ParserConfig) -> Self {
        Parser { hasher, config, line_no: 0 }
    }

    /// Parse one line into a hashed instance.
    pub fn parse_line(&mut self, line: &str) -> Result<Instance, ParseError> {
        let mut inst = Instance::new(0.0, Vec::new());
        self.parse_line_into(line, &mut inst)?;
        Ok(inst)
    }

    /// Parse one line into a *reused* instance (the streaming hot path:
    /// no per-line allocation once `inst.features` has grown to the
    /// stream's working capacity). On error `inst` is unspecified.
    pub fn parse_line_into(
        &mut self,
        line: &str,
        inst: &mut Instance,
    ) -> Result<(), ParseError> {
        self.line_no += 1;
        let line = line.trim();
        if line.is_empty() {
            return Err(ParseError::Empty);
        }
        let (head, rest) = match line.find('|') {
            Some(p) => (&line[..p], &line[p..]),
            None => (line, ""),
        };

        // head: label [importance] ['tag]
        let mut label = 0.0;
        let mut weight = 1.0f32;
        let mut tag = self.line_no;
        let mut saw_label = false;
        for tok in head.split_whitespace() {
            if let Some(t) = tok.strip_prefix('\'') {
                // numeric tags kept; others hashed for stability
                tag = t
                    .parse::<u64>()
                    .unwrap_or_else(|_| crate::hashing::murmur3_32(t.as_bytes(), 0) as u64);
            } else if !saw_label {
                label = tok
                    .parse::<f64>()
                    .map_err(|_| ParseError::BadLabel(tok.into()))?;
                saw_label = true;
            } else {
                weight = tok
                    .parse::<f32>()
                    .map_err(|_| ParseError::BadValue(tok.into()))?;
            }
        }
        if !saw_label {
            return Err(ParseError::BadLabel(head.into()));
        }

        // namespace sections (into the caller's recycled buffer)
        inst.features.clear();
        let features = &mut inst.features;
        // per-namespace-initial hashed indices, for quadratic expansion
        let mut by_initial: Vec<(char, Vec<u32>)> = Vec::new();
        for section in rest.split('|').skip(1) {
            let mut toks = section.split_whitespace();
            let (ns_name, ns_scale) = match toks.next() {
                // "|ns" or "|ns:2.0" or "| f" (anonymous namespace: the
                // first token is a feature if the section starts with a
                // space — VW semantics; we approximate by treating a
                // token containing ':' with a numeric tail OR any token
                // as namespace only when the raw section doesn't start
                // with whitespace)
                Some(first) if !section.starts_with(char::is_whitespace) => {
                    let (n, s) = split_scale(first);
                    (n.to_string(), s)
                }
                Some(first) => {
                    // anonymous namespace; `first` is a feature
                    let seed = self.hasher.namespace_seed(b" ");
                    push_feature(&self.hasher, seed, first, 1.0, features)?;
                    (" ".to_string(), 1.0)
                }
                None => (" ".to_string(), 1.0),
            };
            let seed = self.hasher.namespace_seed(ns_name.as_bytes());
            let initial = ns_name.chars().next().unwrap_or(' ');
            let start = features.len();
            for tok in toks {
                push_feature(&self.hasher, seed, tok, ns_scale, features)?;
            }
            if self.config.quadratic.iter().any(|&(a, b)| a == initial || b == initial)
            {
                let idxs: Vec<u32> =
                    features[start..].iter().map(|&(i, _)| i).collect();
                match by_initial.iter_mut().find(|(c, _)| *c == initial) {
                    Some((_, v)) => v.extend(idxs),
                    None => by_initial.push((initial, idxs)),
                }
            }
        }

        // quadratic (outer-product) expansion, never read from disk (§0.2)
        for &(a, b) in &self.config.quadratic {
            let left = by_initial.iter().find(|(c, _)| *c == a);
            let right = by_initial.iter().find(|(c, _)| *c == b);
            if let (Some((_, ls)), Some((_, rs))) = (left, right) {
                for &li in ls {
                    for &ri in rs {
                        let (idx, sign) = self.hasher.hash_pair(li, ri);
                        features.push((idx, sign));
                    }
                }
            }
        }

        inst.label = label;
        inst.weight = weight;
        inst.tag = tag;
        Ok(())
    }

    /// Parse a whole reader into a dataset, skipping malformed lines.
    pub fn parse_all(
        &mut self,
        text: &str,
        name: &str,
    ) -> crate::data::Dataset {
        let mut ds = crate::data::Dataset::new(name, self.hasher.table_size());
        for line in text.lines() {
            if let Ok(inst) = self.parse_line(line) {
                ds.instances.push(inst);
            }
        }
        ds
    }
}

fn split_scale(tok: &str) -> (&str, f32) {
    match tok.rsplit_once(':') {
        Some((name, s)) => match s.parse::<f32>() {
            Ok(v) => (name, v),
            Err(_) => (tok, 1.0),
        },
        None => (tok, 1.0),
    }
}

fn push_feature(
    hasher: &FeatureHasher,
    seed: u32,
    tok: &str,
    scale: f32,
    out: &mut Vec<SparseFeat>,
) -> Result<(), ParseError> {
    let (name, value) = match tok.rsplit_once(':') {
        Some((n, v)) => (
            n,
            v.parse::<f32>().map_err(|_| ParseError::BadValue(tok.into()))?,
        ),
        None => (tok, 1.0),
    };
    let (idx, sign) = hasher.hash(seed, name.as_bytes());
    out.push((idx, sign * value * scale));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new(FeatureHasher::new(18), ParserConfig::default())
    }

    #[test]
    fn basic_line() {
        let mut p = parser();
        let inst = p.parse_line("1 |f a b:2.5 c").unwrap();
        assert_eq!(inst.label, 1.0);
        assert_eq!(inst.features.len(), 3);
        assert_eq!(inst.features[1].1, 2.5);
    }

    #[test]
    fn importance_and_tag() {
        let mut p = parser();
        let inst = p.parse_line("-1 0.25 '77 |x q").unwrap();
        assert_eq!(inst.label, -1.0);
        assert_eq!(inst.weight, 0.25);
        assert_eq!(inst.tag, 77);
    }

    #[test]
    fn namespace_scale() {
        let mut p = parser();
        let inst = p.parse_line("0 |ns:2 a:3").unwrap();
        assert_eq!(inst.features[0].1, 6.0);
    }

    #[test]
    fn namespaces_hash_differently() {
        let mut p = parser();
        let a = p.parse_line("1 |user x").unwrap();
        let b = p.parse_line("1 |ad x").unwrap();
        assert_ne!(a.features[0].0, b.features[0].0);
    }

    #[test]
    fn quadratic_expansion() {
        let mut p = Parser::new(
            FeatureHasher::new(18),
            ParserConfig { quadratic: vec![('u', 'a')] },
        );
        let inst = p.parse_line("1 |user x y |ad z").unwrap();
        // 3 base features + 2×1 cross features
        assert_eq!(inst.features.len(), 5);
    }

    #[test]
    fn bad_label_rejected() {
        let mut p = parser();
        assert!(matches!(
            p.parse_line("abc |f x"),
            Err(ParseError::BadLabel(_))
        ));
        assert_eq!(p.parse_line(""), Err(ParseError::Empty));
    }

    #[test]
    fn parse_all_skips_bad_lines() {
        let mut p = parser();
        let ds = p.parse_all("1 |f a\nbroken\n0 |f b\n", "t");
        assert_eq!(ds.len(), 2);
    }

    #[test]
    fn malformed_labels_rejected() {
        let mut p = parser();
        for line in ["abc |f x", "1..5 |f x", "- |f x", "|f x"] {
            assert!(
                matches!(p.parse_line(line), Err(ParseError::BadLabel(_))),
                "{line:?} must be a BadLabel"
            );
        }
        // a malformed importance weight is a value error, not a label one
        assert!(matches!(
            p.parse_line("1 heavy |f x"),
            Err(ParseError::BadValue(_))
        ));
    }

    #[test]
    fn empty_namespaces_are_harmless() {
        let mut p = parser();
        // empty named namespace, empty anonymous namespace, namespace
        // with only a scale: all parse to an instance with no features
        for line in ["1 |", "1 | ", "1 |f", "1 |f |g", "1 |ns:2"] {
            let inst = p.parse_line(line).unwrap_or_else(|e| {
                panic!("{line:?} must parse, got {e}")
            });
            assert!(inst.features.is_empty(), "{line:?}");
            assert_eq!(inst.label, 1.0);
        }
        // an empty namespace between populated ones drops nothing: 'x'
        // in |a, then 'b' and 'y' in the trailing anonymous namespace
        let inst = p.parse_line("1 |a x || b y").unwrap();
        assert_eq!(inst.features.len(), 3);
    }

    #[test]
    fn truncated_lines_rejected_or_degrade() {
        let mut p = parser();
        // feature with a dangling ':' value is malformed
        assert!(matches!(
            p.parse_line("1 |f a:"),
            Err(ParseError::BadValue(_))
        ));
        assert!(matches!(
            p.parse_line("1 |f a:1.5e"),
            Err(ParseError::BadValue(_))
        ));
        // a line cut after the label is a featureless but valid instance
        let inst = p.parse_line("1").unwrap();
        assert!(inst.features.is_empty());
        // cut mid-tag: tag hashes, does not crash
        let inst = p.parse_line("1 'x |f a").unwrap();
        assert_eq!(inst.features.len(), 1);
    }

    #[test]
    fn parse_line_into_reuses_buffers_and_matches() {
        let mut p1 = parser();
        let mut p2 = parser();
        let mut reused = crate::data::instance::Instance::new(0.0, Vec::new());
        for line in ["1 |f a b:2.5 c", "-1 0.25 '77 |x q", "0 |ns:2 a:3"] {
            p1.parse_line_into(line, &mut reused).unwrap();
            let fresh = p2.parse_line(line).unwrap();
            assert_eq!(reused, fresh, "{line:?}");
        }
        // after an error, the next parse still lands cleanly
        assert!(p1.parse_line_into("bad |f x", &mut reused).is_err());
        p1.parse_line_into("1 |f a", &mut reused).unwrap();
        let mut p3 = parser();
        p3.parse_line("1 |f a b:2.5 c").unwrap();
        p3.parse_line("-1 0.25 '77 |x q").unwrap();
        p3.parse_line("0 |ns:2 a:3").unwrap();
        p3.parse_line("bad |f x").ok();
        assert_eq!(reused, p3.parse_line("1 |f a").unwrap());
    }

    #[test]
    fn same_line_same_hashes() {
        let mut p1 = parser();
        let mut p2 = parser();
        assert_eq!(
            p1.parse_line("1 |f a b c").unwrap().features,
            p2.parse_line("1 |f a b c").unwrap().features
        );
    }
}
