//! Instance sharding (Fig 0.1 left) — the baseline the paper argues
//! against for online learning: partition *instances* across n workers,
//! train independently, combine by (weighted) parameter averaging.
//!
//! The delay factor is m/n (§0.3): information from an instance on one
//! shard reaches the others only at the next combine. We implement the
//! standard iterate-average scheme (Mann et al. 2009; McDonald et al.
//! 2010): E epochs of {train each shard locally, average weights,
//! re-broadcast}.

use crate::data::Dataset;
use crate::learner::sgd::Sgd;
use crate::loss::Loss;
use crate::lr::LrSchedule;

#[derive(Clone, Debug)]
/// Instance-level sharding baseline: train shards independently, average.
pub struct InstanceSharder {
    /// Number of shards.
    pub shards: usize,
}

impl InstanceSharder {
    /// A sharder over `shards` shards.
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1);
        InstanceSharder { shards }
    }

    /// Round-robin partition of instance indices.
    pub fn partition(&self, n: usize) -> Vec<Vec<usize>> {
        let mut parts = vec![Vec::with_capacity(n / self.shards + 1); self.shards];
        for i in 0..n {
            parts[i % self.shards].push(i);
        }
        parts
    }

    /// Train-with-averaging: each epoch trains every shard from the
    /// current averaged weights, then averages. Returns the final
    /// averaged weights.
    pub fn train_averaged(
        &self,
        ds: &Dataset,
        loss: Loss,
        lr: LrSchedule,
        epochs: usize,
    ) -> Vec<f32> {
        let parts = self.partition(ds.len());
        let mut avg = vec![0.0f32; ds.dim];
        for _ in 0..epochs.max(1) {
            let mut acc = vec![0.0f64; ds.dim];
            for part in &parts {
                let mut learner = Sgd::new(ds.dim, loss, lr);
                learner.w.copy_from_slice(&avg);
                for &idx in part {
                    let inst = &ds.instances[idx];
                    learner.learn(&inst.features, inst.label);
                }
                for (a, &w) in acc.iter_mut().zip(learner.weights()) {
                    *a += w as f64;
                }
            }
            for (dst, &a) in avg.iter_mut().zip(&acc) {
                *dst = (a / self.shards as f64) as f32;
            }
        }
        avg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{RcvLikeGen, SynthConfig};

    #[test]
    fn partition_covers_all() {
        let s = InstanceSharder::new(3);
        let parts = s.partition(10);
        let mut all: Vec<usize> = parts.concat();
        all.sort();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn averaging_learns() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 4_000,
            features: 300,
            density: 15,
            ..Default::default()
        })
        .generate();
        let (train, test) = ds.split_test(0.2);
        let w = InstanceSharder::new(4).train_averaged(
            &train,
            Loss::Logistic,
            LrSchedule::inv_sqrt(4.0, 1.0),
            3,
        );
        let (_, acc) = crate::metrics::test_metrics(
            Loss::Logistic,
            |x| crate::linalg::sparse_dot(&w, x),
            &test.instances,
        );
        assert!(acc > 0.65, "acc {acc}");
    }

    #[test]
    fn single_shard_single_epoch_equals_sgd() {
        let ds = RcvLikeGen::new(SynthConfig {
            instances: 500,
            features: 100,
            density: 10,
            ..Default::default()
        })
        .generate();
        let s = InstanceSharder::new(1);
        let w = s.train_averaged(&ds, Loss::Squared, LrSchedule::constant(0.05), 1);
        let mut sgd = Sgd::new(ds.dim, Loss::Squared, LrSchedule::constant(0.05));
        for inst in ds.iter() {
            sgd.learn(&inst.features, inst.label);
        }
        assert_eq!(w, sgd.w);
    }
}
