//! Data decomposition (§0.3, Figure 0.1): instance shards and feature
//! shards.

pub mod feature;
pub mod instance_shard;

pub use feature::FeatureSharder;
pub use instance_shard::InstanceSharder;
