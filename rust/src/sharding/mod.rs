//! `pol::sharding` — data decomposition (§0.3, Figure 0.1), with one
//! routing authority.
//!
//! The paper's design space is *feature sharding*: split every
//! instance's features across n workers and combine their predictions
//! (Fig 0.1 right). [`ShardPlan`] is the crate's single source of truth
//! for that routing — assignment kind (hash or range), shard count,
//! dimension, and a stable signature — and the *same* plan object flows
//! through every layer:
//!
//! * ingest — [`crate::stream::Pipeline`] optionally shards on the
//!   background parse thread,
//! * training — the [`crate::coordinator::Coordinator`] forward sweep
//!   and the §0.5.1 [`crate::coordinator::multicore`] learner threads,
//! * durability — the `.polz` codec serializes the plan into the v3
//!   header and verifies its signature on load,
//! * serving — [`crate::serve::snapshot::TreePredictor`] splits request
//!   features with the checkpointed plan.
//!
//! No consumer re-derives `shard_of` or branches on assignment kind;
//! they hold a plan and ask it.
//!
//! ## Elastic worker counts
//!
//! [`ShardPlan::remap`] yields a [`ShardMigration`] that re-keys
//! per-shard weight tables between shard counts — every (feature,
//! weight) pair moves to its new owner bit-exactly, and `n→m→n` is the
//! identity. On top of it, `Coordinator::reshard`,
//! `SessionBuilder::workers` (warm starts migrate instead of erroring),
//! `MulticoreTrainer::resume_source`, and the CLI's `pol reshard`
//! make the paper's parallelism/delay tradeoff a *runtime* knob: train
//! at 4 workers, resume at 8, serve at 2, from the same checkpoint.
//!
//! [`InstanceSharder`] is the Fig 0.1 *left* baseline the paper argues
//! against for online learning — partition instances, average
//! parameters — kept for the comparison experiments.

/// Instance-level (example) sharding baseline.
pub mod instance_shard;
/// First-class shard plans and migrations.
pub mod plan;

pub use instance_shard::InstanceSharder;
pub use plan::{ShardKind, ShardMigration, ShardPlan};
