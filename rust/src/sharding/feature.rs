//! Feature sharding (Fig 0.1 right, Fig 0.4 step (b)).
//!
//! Split each instance's features across n shards, replicating the label
//! to every shard. Assignment is by hash of the feature index — stateless
//! and namespace-oblivious, so the shard step is "completely
//! parallelizable" as the paper notes. Contiguous-range assignment is
//! also provided for the dense/runtime path, where shard s owns the
//! index range [s·d/n, (s+1)·d/n).

use crate::data::instance::Instance;
use crate::linalg::SparseFeat;

/// How features map to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardAssign {
    /// shard = mix(index) mod n — balanced for arbitrary index sets.
    Hash,
    /// shard = index / (dim/n) — contiguous ranges (dense-block friendly).
    Range { dim: u32 },
}

/// Splits instances into per-shard projected instances.
#[derive(Clone, Debug)]
pub struct FeatureSharder {
    pub shards: usize,
    pub assign: ShardAssign,
}

impl FeatureSharder {
    pub fn hash(shards: usize) -> Self {
        assert!(shards >= 1);
        FeatureSharder { shards, assign: ShardAssign::Hash }
    }

    pub fn range(shards: usize, dim: u32) -> Self {
        assert!(shards >= 1 && dim as usize >= shards);
        FeatureSharder { shards, assign: ShardAssign::Range { dim } }
    }

    /// Stable identity of this sharder's routing function, folded into
    /// checkpoint config digests: a serving process must split features
    /// exactly like the training process did, so a snapshot records this
    /// signature and loaders verify it.
    pub fn signature(&self) -> u64 {
        let tag = match self.assign {
            ShardAssign::Hash => format!("hash:{}", self.shards),
            ShardAssign::Range { dim } => format!("range:{}:{dim}", self.shards),
        };
        crate::hashing::fnv1a64(tag.as_bytes())
    }

    /// Which shard owns feature index `i`.
    #[inline]
    pub fn shard_of(&self, i: u32) -> usize {
        match self.assign {
            ShardAssign::Hash => {
                // avalanche the index so contiguous hashed features spread
                let mut h = i as u64;
                h ^= h >> 16;
                h = h.wrapping_mul(0x45d9f3b);
                h ^= h >> 16;
                (h % self.shards as u64) as usize
            }
            ShardAssign::Range { dim } => {
                let per = dim.div_ceil(self.shards as u32);
                ((i / per) as usize).min(self.shards - 1)
            }
        }
    }

    /// Split one instance into `shards` projected instances (labels and
    /// tags replicated — Fig 0.4 step (b)).
    pub fn split(&self, inst: &Instance) -> Vec<Instance> {
        let mut parts: Vec<Vec<SparseFeat>> =
            vec![Vec::with_capacity(inst.features.len() / self.shards + 1); self.shards];
        for &(i, v) in &inst.features {
            parts[self.shard_of(i)].push((i, v));
        }
        parts
            .into_iter()
            .map(|features| Instance {
                label: inst.label,
                weight: inst.weight,
                features,
                tag: inst.tag,
            })
            .collect()
    }

    /// Split into preallocated buffers (hot path; avoids the per-call
    /// Vec-of-Vec allocation).
    pub fn split_into(&self, inst: &Instance, out: &mut [Vec<SparseFeat>]) {
        self.split_features_into(&inst.features, out);
    }

    /// Slice-based variant of [`Self::split_into`] — the coordinator's
    /// per-instance path, which must not clone or wrap the features.
    pub fn split_features_into(
        &self,
        features: &[SparseFeat],
        out: &mut [Vec<SparseFeat>],
    ) {
        assert_eq!(out.len(), self.shards);
        for buf in out.iter_mut() {
            buf.clear();
        }
        for &(i, v) in features {
            out[self.shard_of(i)].push((i, v));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(n: u32) -> Instance {
        Instance::new(1.0, (0..n).map(|i| (i * 7 + 3, 1.0)).collect())
    }

    #[test]
    fn split_partitions_features() {
        let s = FeatureSharder::hash(4);
        let i = inst(100);
        let parts = s.split(&i);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.features.len()).sum();
        assert_eq!(total, 100);
        // disjointness: every feature appears in exactly the shard that
        // owns it
        for (sidx, p) in parts.iter().enumerate() {
            for &(fi, _) in &p.features {
                assert_eq!(s.shard_of(fi), sidx);
            }
        }
    }

    #[test]
    fn labels_replicated() {
        let s = FeatureSharder::hash(3);
        for p in s.split(&inst(10)) {
            assert_eq!(p.label, 1.0);
        }
    }

    #[test]
    fn hash_assign_balanced() {
        let s = FeatureSharder::hash(8);
        let mut counts = vec![0usize; 8];
        for i in 0..80_000u32 {
            counts[s.shard_of(i)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 1_000.0, "counts {counts:?}");
        }
    }

    #[test]
    fn range_assign_contiguous() {
        let s = FeatureSharder::range(4, 100);
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(24), 0);
        assert_eq!(s.shard_of(25), 1);
        assert_eq!(s.shard_of(99), 3);
    }

    #[test]
    fn single_shard_is_identity() {
        let s = FeatureSharder::hash(1);
        let i = inst(10);
        let parts = s.split(&i);
        assert_eq!(parts[0].features, i.features);
    }

    #[test]
    fn split_into_matches_split() {
        let s = FeatureSharder::hash(4);
        let i = inst(50);
        let parts = s.split(&i);
        let mut bufs: Vec<Vec<SparseFeat>> = vec![Vec::new(); 4];
        s.split_into(&i, &mut bufs);
        for (p, b) in parts.iter().zip(&bufs) {
            assert_eq!(&p.features, b);
        }
    }
}
