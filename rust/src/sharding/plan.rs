//! [`ShardPlan`] — the one feature-routing authority.
//!
//! Every layer that needs to know "which shard owns feature i" — the
//! ingest [`crate::stream::Pipeline`], the
//! [`crate::coordinator::Coordinator`]'s forward sweep, the §0.5.1
//! multicore learner threads, the `.polz` checkpoint codec, and the
//! serving [`crate::serve::snapshot::TreePredictor`] — holds a
//! `ShardPlan` and asks it. Nothing outside this module re-derives the
//! routing function; the plan is the single object threaded through the
//! whole stack, so training, checkpointing, and serving can never
//! disagree about where a feature lives.
//!
//! A plan owns four things:
//! * the **assignment kind** ([`ShardKind::Hash`] — balanced for
//!   arbitrary index sets — or [`ShardKind::Range`] — contiguous
//!   dense-block-friendly ranges, shard s owning `[s·⌈d/n⌉, …)`),
//! * the **shard count** (the paper's worker count n),
//! * the **dimension** (the hashed feature space the routing covers),
//! * a stable **signature** folded into checkpoint digests, so a model
//!   is never served or warm-started against a different routing than
//!   it was trained with.
//!
//! ## Elastic re-sharding
//!
//! [`ShardPlan::remap`] produces a [`ShardMigration`] between the same
//! routing at two shard counts. Migration re-keys per-shard weight
//! tables feature by feature — each weight moves from its old owner to
//! its new owner, bit-exactly — so a checkpoint trained at n workers
//! warm-starts and serves at m workers:
//!
//! * every (feature, weight) pair is preserved exactly, for hash and
//!   range assignment alike;
//! * `remap(n→m→n)` is the identity on plan-consistent tables (the
//!   moves are a bijection per feature);
//! * a flat (worker-invariant) table is untouched — predictions are
//!   bit-identical at any worker count, which is exactly the paper's
//!   Fig 0.6 observation that SGD/minibatch/CG do not depend on n.
//!
//! The degree of parallelism becomes a runtime knob ("Slow Learners are
//! Fast" treats it the same way), not a constructor constant.

use crate::data::instance::Instance;
use crate::linalg::SparseFeat;
use crate::topology::Topology;

/// How a [`ShardPlan`] maps feature indices to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    /// shard = mix(index) mod n — balanced for arbitrary index sets.
    Hash,
    /// shard = index / ⌈dim/n⌉ — contiguous ranges (dense-block
    /// friendly).
    Range,
}

impl ShardKind {
    /// Canonical kind name.
    pub fn name(&self) -> &'static str {
        match self {
            ShardKind::Hash => "hash",
            ShardKind::Range => "range",
        }
    }
}

/// The routing function: assignment kind + shard count + dimension,
/// with a stable signature. See the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    kind: ShardKind,
    shards: usize,
    dim: usize,
}

/// FNV-1a fold of one byte (the checkpoint hash, inlined so signatures
/// never allocate).
#[inline]
const fn fold_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x100000001b3)
}

#[inline]
fn fold_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = fold_byte(h, b);
    }
    h
}

/// Fold the decimal digits of `v` (most significant first) — exactly
/// the bytes `format!("{v}")` would produce, without the heap `String`.
#[inline]
fn fold_decimal(h: u64, v: u64) -> u64 {
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    let mut v = v;
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    fold_bytes(h, &buf[i..])
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// Serialized plan size in checkpoint headers (kind + shards + dim).
pub const WIRE_LEN: usize = 13;

impl ShardPlan {
    /// Hash assignment over `shards` shards of a `dim`-sized feature
    /// space.
    pub fn hash(shards: usize, dim: usize) -> ShardPlan {
        let dim = dim.clamp(1, u32::MAX as usize);
        assert!(shards >= 1, "a plan needs at least one shard");
        ShardPlan { kind: ShardKind::Hash, shards, dim }
    }

    /// Contiguous-range assignment: shard s owns `[s·⌈dim/n⌉, …)`.
    /// Feature indices are `u32`, so `dim` must fit in one.
    pub fn range(shards: usize, dim: usize) -> ShardPlan {
        assert!(
            shards >= 1 && dim >= shards,
            "range plans need dim >= shards >= 1"
        );
        assert!(
            dim <= u32::MAX as usize,
            "feature indices are u32; dim must fit"
        );
        ShardPlan { kind: ShardKind::Range, shards, dim }
    }

    /// The plan a [`Topology`] trains under: one hash shard per leaf
    /// (the coordinator's historical routing, kept so existing
    /// checkpoint signatures stay valid).
    pub fn for_topology(topology: &Topology, dim: usize) -> ShardPlan {
        ShardPlan::hash(topology.leaves(), dim)
    }

    /// The sharding kind.
    pub fn kind(&self) -> ShardKind {
        self.kind
    }

    /// Worker / shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Hashed feature-space size the routing covers.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Human-readable identity for error messages and reports.
    pub fn describe(&self) -> String {
        format!(
            "{} sharding over {} shard(s), dim {}",
            self.kind.name(),
            self.shards,
            self.dim
        )
    }

    /// Stable identity of the routing function, folded into checkpoint
    /// config digests: a serving or warm-starting process must split
    /// features exactly like the training process did. Computed by
    /// folding the fields straight into the FNV state — no per-call
    /// allocation — and pinned by unit test to the historical digests
    /// (`"hash:{n}"` / `"range:{n}:{dim}"`), so existing checkpoints
    /// stay loadable. Hash signatures deliberately exclude the dim:
    /// hash routing does not depend on it, and v1/v2 checkpoints never
    /// recorded it.
    pub fn signature(&self) -> u64 {
        match self.kind {
            ShardKind::Hash => {
                fold_decimal(fold_bytes(FNV_OFFSET, b"hash:"), self.shards as u64)
            }
            ShardKind::Range => {
                let h = fold_bytes(FNV_OFFSET, b"range:");
                let h = fold_decimal(h, self.shards as u64);
                let h = fold_byte(h, b':');
                fold_decimal(h, self.dim as u64)
            }
        }
    }

    /// Which shard owns feature index `i`.
    #[inline]
    pub fn shard_of(&self, i: u32) -> usize {
        match self.kind {
            ShardKind::Hash => {
                // avalanche the index so contiguous hashed features
                // spread
                let mut h = i as u64;
                h ^= h >> 16;
                h = h.wrapping_mul(0x45d9f3b);
                h ^= h >> 16;
                (h % self.shards as u64) as usize
            }
            ShardKind::Range => {
                let per = (self.dim as u32).div_ceil(self.shards as u32);
                ((i / per) as usize).min(self.shards - 1)
            }
        }
    }

    /// Split one instance into `shards` projected instances (labels and
    /// tags replicated — Fig 0.4 step (b)).
    pub fn split(&self, inst: &Instance) -> Vec<Instance> {
        let mut parts: Vec<Vec<SparseFeat>> =
            vec![
                Vec::with_capacity(inst.features.len() / self.shards + 1);
                self.shards
            ];
        for &(i, v) in &inst.features {
            parts[self.shard_of(i)].push((i, v));
        }
        parts
            .into_iter()
            .map(|features| Instance {
                label: inst.label,
                weight: inst.weight,
                features,
                tag: inst.tag,
            })
            .collect()
    }

    /// Split into preallocated buffers (hot path; avoids the per-call
    /// Vec-of-Vec allocation).
    pub fn split_into(&self, inst: &Instance, out: &mut [Vec<SparseFeat>]) {
        self.split_features_into(&inst.features, out);
    }

    /// Slice-based variant of [`Self::split_into`] — the coordinator's
    /// per-instance path, which must not clone or wrap the features.
    pub fn split_features_into(
        &self,
        features: &[SparseFeat],
        out: &mut [Vec<SparseFeat>],
    ) {
        assert_eq!(out.len(), self.shards);
        for buf in out.iter_mut() {
            buf.clear();
        }
        for &(i, v) in features {
            out[self.shard_of(i)].push((i, v));
        }
    }

    /// Distribute a flat `dim`-length weight table into per-shard
    /// tables: each shard's table holds exactly the weights of the
    /// indices it owns, zero elsewhere. The multicore warm-start path:
    /// seeding k learner threads from a merged table.
    pub fn split_table(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(flat.len(), self.dim, "table length must match plan dim");
        let mut parts = vec![vec![0.0f32; self.dim]; self.shards];
        for (i, &w) in flat.iter().enumerate() {
            if w.to_bits() != 0 {
                parts[self.shard_of(i as u32)][i] = w;
            }
        }
        parts
    }

    /// Reassemble a flat table from per-shard tables by owner
    /// selection. Bit-exact (including `-0.0`), and equal to the
    /// element-wise sum whenever the tables are plan-consistent (only
    /// owners hold non-zero entries).
    pub fn merge_tables<T: AsRef<[f32]>>(&self, parts: &[T]) -> Vec<f32> {
        assert_eq!(parts.len(), self.shards, "one table per shard");
        let mut flat = vec![0.0f32; self.dim];
        for (i, slot) in flat.iter_mut().enumerate() {
            *slot = parts[self.shard_of(i as u32)].as_ref()[i];
        }
        flat
    }

    /// Whether per-shard tables respect this plan's ownership: every
    /// non-zero entry sits in the table of the shard that owns its
    /// index. Migration is lossless exactly on plan-consistent tables.
    pub fn consistent<T: AsRef<[f32]>>(&self, parts: &[T]) -> bool {
        if parts.len() != self.shards {
            return false;
        }
        parts.iter().enumerate().all(|(s, t)| {
            let t = t.as_ref();
            t.len() == self.dim
                && t.iter().enumerate().all(|(i, w)| {
                    w.to_bits() == 0 || self.shard_of(i as u32) == s
                })
        })
    }

    /// The migration from this plan to the same routing kind (and dim)
    /// at `new_shards` shards — the elastic worker-count knob.
    pub fn remap(&self, new_shards: usize) -> ShardMigration {
        assert!(new_shards >= 1, "a plan needs at least one shard");
        let to = match self.kind {
            ShardKind::Hash => ShardPlan::hash(new_shards, self.dim),
            ShardKind::Range => ShardPlan::range(new_shards, self.dim),
        };
        ShardMigration { from: *self, to }
    }

    /// Fixed-size header encoding for the `.polz` v3 framing
    /// (kind byte, u32 shard count, u64 dim — little-endian).
    pub fn to_wire(&self) -> [u8; WIRE_LEN] {
        let mut out = [0u8; WIRE_LEN];
        out[0] = match self.kind {
            ShardKind::Hash => 0,
            ShardKind::Range => 1,
        };
        out[1..5].copy_from_slice(&(self.shards as u32).to_le_bytes());
        out[5..13].copy_from_slice(&(self.dim as u64).to_le_bytes());
        out
    }

    /// Inverse of [`Self::to_wire`]. `None` for an unknown kind byte or
    /// field values no constructor would accept.
    pub fn from_wire(bytes: &[u8; WIRE_LEN]) -> Option<ShardPlan> {
        let shards = crate::bytes::le_u32(&bytes[1..5]) as usize;
        let dim = crate::bytes::le_u64(&bytes[5..13]);
        // feature indices are u32: a dim that cannot fit would make the
        // range arithmetic divide by a truncated zero
        if shards == 0 || dim == 0 || dim > u32::MAX as u64 {
            return None;
        }
        let dim = dim as usize;
        match bytes[0] {
            0 => Some(ShardPlan::hash(shards, dim)),
            1 if dim >= shards => Some(ShardPlan::range(shards, dim)),
            _ => None,
        }
    }
}

/// An exact re-keying of per-shard weight tables between two shard
/// counts of the same routing (see [`ShardPlan::remap`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMigration {
    from: ShardPlan,
    to: ShardPlan,
}

impl ShardMigration {
    /// The plan being migrated away from.
    pub fn from_plan(&self) -> ShardPlan {
        self.from
    }

    /// The plan being migrated to.
    pub fn to_plan(&self) -> ShardPlan {
        self.to
    }

    /// A no-op migration (same shard count both sides).
    pub fn is_identity(&self) -> bool {
        self.from == self.to
    }

    /// Re-key per-shard full-width weight tables: for every feature
    /// index, the weight held by its old owner moves to its new owner,
    /// bit-exactly (including `-0.0`). Entries outside a shard's
    /// ownership are structurally zero in any plan-consistent model and
    /// are ignored. `remap(n→m→n)` composed through this method is the
    /// identity.
    pub fn migrate_tables<T: AsRef<[f32]>>(&self, old: &[T]) -> Vec<Vec<f32>> {
        assert_eq!(
            old.len(),
            self.from.shards,
            "one table per source shard"
        );
        let dim = self.from.dim;
        for t in old {
            assert_eq!(t.as_ref().len(), dim, "table length must match dim");
        }
        let mut new = vec![vec![0.0f32; dim]; self.to.shards];
        for i in 0..dim {
            let w = old[self.from.shard_of(i as u32)].as_ref()[i];
            if w.to_bits() != 0 {
                new[self.to.shard_of(i as u32)][i] = w;
            }
        }
        new
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::fnv1a64;

    fn inst(n: u32) -> Instance {
        Instance::new(1.0, (0..n).map(|i| (i * 7 + 3, 1.0)).collect())
    }

    #[test]
    fn split_partitions_features() {
        let s = ShardPlan::hash(4, 1024);
        let i = inst(100);
        let parts = s.split(&i);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.features.len()).sum();
        assert_eq!(total, 100);
        // disjointness: every feature appears in exactly the shard that
        // owns it
        for (sidx, p) in parts.iter().enumerate() {
            for &(fi, _) in &p.features {
                assert_eq!(s.shard_of(fi), sidx);
            }
        }
    }

    #[test]
    fn labels_replicated() {
        let s = ShardPlan::hash(3, 1024);
        for p in s.split(&inst(10)) {
            assert_eq!(p.label, 1.0);
        }
    }

    #[test]
    fn hash_assign_balanced() {
        let s = ShardPlan::hash(8, 80_000);
        let mut counts = vec![0usize; 8];
        for i in 0..80_000u32 {
            counts[s.shard_of(i)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 1_000.0, "counts {counts:?}");
        }
    }

    #[test]
    fn range_assign_contiguous() {
        let s = ShardPlan::range(4, 100);
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(24), 0);
        assert_eq!(s.shard_of(25), 1);
        assert_eq!(s.shard_of(99), 3);
    }

    #[test]
    fn single_shard_is_identity() {
        let s = ShardPlan::hash(1, 1024);
        let i = inst(10);
        let parts = s.split(&i);
        assert_eq!(parts[0].features, i.features);
    }

    #[test]
    fn split_into_matches_split() {
        let s = ShardPlan::hash(4, 1024);
        let i = inst(50);
        let parts = s.split(&i);
        let mut bufs: Vec<Vec<SparseFeat>> = vec![Vec::new(); 4];
        s.split_into(&i, &mut bufs);
        for (p, b) in parts.iter().zip(&bufs) {
            assert_eq!(&p.features, b);
        }
    }

    #[test]
    fn signature_matches_historical_string_digest() {
        // the signature must stay byte-compatible with the original
        // format!-based implementation: checkpoints written before
        // ShardPlan existed must keep loading
        for shards in [1usize, 2, 3, 7, 8, 64] {
            let plan = ShardPlan::hash(shards, 4096);
            let tag = format!("hash:{shards}");
            assert_eq!(plan.signature(), fnv1a64(tag.as_bytes()), "{tag}");
        }
        for (shards, dim) in [(1usize, 32usize), (4, 4096), (8, 65_536)] {
            let plan = ShardPlan::range(shards, dim);
            let tag = format!("range:{shards}:{dim}");
            assert_eq!(plan.signature(), fnv1a64(tag.as_bytes()), "{tag}");
        }
    }

    #[test]
    fn signature_pinned_values() {
        // literal digests, so any change to the fold (or to fnv1a64
        // itself) that would orphan existing checkpoints fails loudly
        assert_eq!(ShardPlan::hash(1, 999).signature(), 0x3da8d2e701217960);
        assert_eq!(ShardPlan::hash(2, 1).signature(), 0x3da8d5e701217e79);
        assert_eq!(ShardPlan::hash(4, 4096).signature(), 0x3da8d7e7012181df);
        assert_eq!(ShardPlan::hash(8, 123).signature(), 0x3da8dbe7012188ab);
        assert_eq!(ShardPlan::hash(16, 7).signature(), 0xe757b486ebe12d22);
        assert_eq!(
            ShardPlan::range(4, 4096).signature(),
            0x2f1309f7693fcef9
        );
        assert_eq!(
            ShardPlan::range(8, 65_536).signature(),
            0xf2e790773c5490eb
        );
        assert_eq!(ShardPlan::range(1, 32).signature(), 0xd1899771c4bd96a6);
    }

    #[test]
    fn hash_signature_ignores_dim() {
        assert_eq!(
            ShardPlan::hash(4, 16).signature(),
            ShardPlan::hash(4, 1 << 20).signature()
        );
        assert_ne!(
            ShardPlan::range(4, 16).signature(),
            ShardPlan::range(4, 32).signature()
        );
    }

    /// Plan-consistent tables with distinctive bit patterns (including
    /// a `-0.0`).
    fn owned_tables(plan: &ShardPlan) -> Vec<Vec<f32>> {
        let mut t = vec![vec![0.0f32; plan.dim()]; plan.shards()];
        for i in 0..plan.dim() {
            let w = match i % 5 {
                0 => 0.0,
                1 => -0.0,
                _ => (i as f32 + 0.5) * if i % 2 == 0 { -1.0 } else { 1.0 },
            };
            if w.to_bits() != 0 {
                t[plan.shard_of(i as u32)][i] = w;
            }
        }
        t
    }

    #[test]
    fn migrate_preserves_every_feature_weight_pair() {
        for plan in [ShardPlan::hash(5, 257), ShardPlan::range(5, 257)] {
            let old = owned_tables(&plan);
            let mig = plan.remap(3);
            let new = mig.migrate_tables(&old);
            assert!(mig.to_plan().consistent(&new));
            for i in 0..plan.dim() {
                let a = old[plan.shard_of(i as u32)][i];
                let b = new[mig.to_plan().shard_of(i as u32)][i];
                assert_eq!(a.to_bits(), b.to_bits(), "feature {i}");
            }
        }
    }

    #[test]
    fn remap_round_trip_is_identity() {
        for kind in [ShardKind::Hash, ShardKind::Range] {
            for (n, m) in [(1usize, 4usize), (4, 1), (3, 7), (8, 2), (5, 5)] {
                let plan = match kind {
                    ShardKind::Hash => ShardPlan::hash(n, 211),
                    ShardKind::Range => ShardPlan::range(n, 211),
                };
                let old = owned_tables(&plan);
                let there = plan.remap(m).migrate_tables(&old);
                let back =
                    plan.remap(m).to_plan().remap(n).migrate_tables(&there);
                for (a, b) in old.iter().zip(&back) {
                    let ab: Vec<u32> = a.iter().map(|w| w.to_bits()).collect();
                    let bb: Vec<u32> = b.iter().map(|w| w.to_bits()).collect();
                    assert_eq!(ab, bb, "{kind:?} {n}->{m}->{n}");
                }
            }
        }
    }

    #[test]
    fn split_and_merge_round_trip_bit_exact() {
        let plan = ShardPlan::hash(4, 100);
        let flat: Vec<f32> = (0..100)
            .map(|i| match i % 4 {
                0 => 0.0,
                1 => -0.0,
                _ => i as f32 - 50.5,
            })
            .collect();
        let parts = plan.split_table(&flat);
        assert!(plan.consistent(&parts));
        let back = plan.merge_tables(&parts);
        for (a, b) in flat.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wire_round_trip() {
        for plan in [
            ShardPlan::hash(1, 1),
            ShardPlan::hash(64, 1 << 20),
            ShardPlan::range(8, 4096),
        ] {
            assert_eq!(ShardPlan::from_wire(&plan.to_wire()), Some(plan));
        }
        assert_eq!(ShardPlan::from_wire(&[0xFF; WIRE_LEN]), None);
        assert_eq!(ShardPlan::from_wire(&[0u8; WIRE_LEN]), None);
        // a dim that cannot fit a u32 feature index is rejected — it
        // would truncate to 0 in the range arithmetic and divide by
        // zero on the first shard_of
        let mut too_big = ShardPlan::range(4, 4096).to_wire();
        too_big[5..13].copy_from_slice(&(1u64 << 32).to_le_bytes());
        assert_eq!(ShardPlan::from_wire(&too_big), None);
    }

    #[test]
    fn consistency_detects_misplaced_weights() {
        let plan = ShardPlan::hash(3, 30);
        let mut t = owned_tables(&plan);
        assert!(plan.consistent(&t));
        // drop a weight in a non-owner table
        let i = (0..30u32).find(|&i| plan.shard_of(i) != 0).unwrap();
        t[0][i as usize] = 9.0;
        assert!(!plan.consistent(&t));
    }
}
