//! # pol — Parallel Online Learning
//!
//! A production-shaped reproduction of *"Parallel Online Learning"*
//! (Hsu, Karampatziakis, Langford & Smola, 2011): feature-sharded online
//! gradient descent with tree architectures, local and global update
//! rules (delayed global, corrective, delayed backpropagation, minibatch
//! gradient descent, minibatch nonlinear conjugate gradient), the
//! deterministic τ-delay schedule, and the paper's full experiment suite
//! (Figures 0.5/0.6, Table 0.1, Propositions 3/4, Theorem-1 delay-regret
//! sweeps, the §0.5.1 multicore path).
//!
//! ## Three-layer architecture (+ the serving layer)
//!
//! * **L3 (this crate)** — the coordinator: data pipeline, feature
//!   hashing + sharding, node topologies, a simulated-network layer with
//!   a virtual clock, every update rule, metrics, the CLI, and the
//!   benches. Pure `std`: nodes are threads, links are `mpsc` channels
//!   with a latency/bandwidth model.
//! * **L2 (python/compile/model.py)** — the jax model: the per-node
//!   online sweep, the master combine step, and the minibatch-CG step,
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the per-node
//!   hot spot, `interpret=True`, checked against a pure-jnp oracle.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts via PJRT (the `xla` crate, behind the `pjrt` cargo
//! feature; the default build stubs it) at startup and serves them from
//! dedicated executor threads.
//!
//! On top of L3 sits **[`serve`]**, the production half: versioned
//! `.polz` checkpoints that round-trip any trained topology
//! bit-identically and warm-start training, plus a train-while-serve
//! prediction server — the coordinator publishes an immutable
//! [`serve::ModelSnapshot`] every K instances through a
//! [`serve::SnapshotPublisher`], and N serving threads answer batched
//! predict requests against the latest snapshot without blocking the
//! training loop, recording instances-behind staleness, latency
//! histograms, and QPS. See `pol checkpoint`, `pol serve`, and
//! `pol predict` in the CLI, `benches/serve_throughput.rs`, and
//! `examples/train_while_serve.rs`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pol::prelude::*;
//!
//! let ds = RcvLikeGen::new(SynthConfig {
//!     instances: 10_000, features: 1_000, ..Default::default()
//! }).generate();
//! let mut learner = Sgd::new(1 << 18, Loss::Squared, LrSchedule::inv_sqrt(0.5, 1.0));
//! let mut pv = ProgressiveValidator::new();
//! for inst in ds.iter() {
//!     let yhat = learner.predict(&inst.features);
//!     pv.observe(yhat, inst.label);
//!     learner.learn(&inst.features, inst.label);
//! }
//! println!("progressive squared loss = {}", pv.mean_loss());
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod hashing;
pub mod learner;
pub mod linalg;
pub mod loss;
pub mod lr;
pub mod metrics;
pub mod net;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sharding;
pub mod topology;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::config::{RunConfig, UpdateRule};
    pub use crate::coordinator::multicore::MulticoreTrainer;
    pub use crate::coordinator::{Coordinator, TrainReport};
    pub use crate::data::instance::Instance;
    pub use crate::data::synth::{
        AdDisplayGen, AdversarialDupGen, RcvLikeGen, SynthConfig,
        WebspamLikeGen,
    };
    pub use crate::data::Dataset;
    pub use crate::hashing::FeatureHasher;
    pub use crate::learner::delayed::DelayedSgd;
    pub use crate::learner::naive_bayes::NaiveBayes;
    pub use crate::learner::node::NodeLearner;
    pub use crate::learner::OnlineLearner;
    pub use crate::learner::sgd::Sgd;
    pub use crate::loss::Loss;
    pub use crate::lr::LrSchedule;
    pub use crate::metrics::ProgressiveValidator;
    pub use crate::net::{LinkSpec, SimNetwork};
    pub use crate::rng::Rng;
    pub use crate::serve::{
        ModelSnapshot, PredictClient, PredictionServer, SnapshotCell,
        SnapshotPublisher,
    };
    pub use crate::topology::Topology;
}
