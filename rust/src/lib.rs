//! # pol — Parallel Online Learning
//!
//! A production-shaped reproduction of *"Parallel Online Learning"*
//! (Hsu, Karampatziakis, Langford & Smola, 2011): feature-sharded online
//! gradient descent with tree architectures, local and global update
//! rules (delayed global, corrective, delayed backpropagation, minibatch
//! gradient descent, minibatch nonlinear conjugate gradient), the
//! deterministic τ-delay schedule, and the paper's full experiment suite
//! (Figures 0.5/0.6, Table 0.1, Propositions 3/4, Theorem-1 delay-regret
//! sweeps, the §0.5.1 multicore path).
//!
//! ## One trait, every architecture
//!
//! The paper's architectures trade off delay, parallelism, and
//! representation power; [`model`] makes swapping them a one-line
//! change. Every trainable predictor — plain [`learner::sgd::Sgd`],
//! centralized coordinators, full sharded trees — implements the
//! object-safe [`model::Model`] trait (predict, scratch-reusing batch
//! predict, streaming learn, dataset training, serving snapshots,
//! `.polz` serialization), and [`model::Session::builder`] is the one
//! construction path the CLI, examples, and benches use. Model-kind
//! branching exists in exactly one place: the checkpoint codec
//! ([`serve::checkpoint`]), where bytes become trait objects.
//!
//! ## Quickstart — train from a stream
//!
//! Data enters through [`stream::InstanceSource`] — a resettable stream
//! of instances backed by a VW-text file ([`stream::VwTextSource`]), a
//! binary cache ([`stream::CacheSource`]), a synthetic generator
//! ([`stream::RcvLikeSource`]), or an in-memory [`data::Dataset`]
//! ([`stream::DatasetSource`]). A [`stream::Pipeline`] parses on a
//! background thread into a bounded pool of recycled batches, so
//! training memory is constant no matter how large the stream — and
//! weights are bit-identical to the in-memory path (stream order *is*
//! the model definition in online learning).
//!
//! ```no_run
//! use pol::prelude::*;
//!
//! let source = RcvLikeSource::new(SynthConfig {
//!     instances: 10_000_000, features: 23_000, ..Default::default()
//! });
//! let mut session = Session::builder()
//!     .source(source)                    // ← or VwTextSource::open(...)
//!     .topology(Topology::TwoLayer { shards: 4 })
//!     .rule(UpdateRule::Local)           // ← swap architectures here
//!     .loss(Loss::Logistic)
//!     .lr(LrSchedule::inv_sqrt(2.0, 1.0))
//!     .clip01(false)
//!     .build()
//!     .expect("build session");
//! let report = session.run().expect("train");
//! println!(
//!     "progressive loss {:.4}, acc {:.4}",
//!     report.progressive.mean_loss(),
//!     report.progressive.accuracy()
//! );
//! ```
//!
//! Already-materialized data trains the same way through
//! [`model::Session::train`] (`session.train(&ds)`), which is now a
//! thin adapter over the same per-instance code path.
//!
//! ## One routing authority, elastic worker counts
//!
//! Feature routing lives in exactly one object:
//! [`sharding::ShardPlan`] (assignment kind, shard count, dimension,
//! signature). The ingest pipeline, the coordinator's forward sweep,
//! the multicore learner threads, the `.polz` codec (which serializes
//! the plan into the v3 header), and the serving tree predictor all
//! hold the *same* plan — no layer re-derives `shard_of`. On top of
//! it, [`sharding::ShardPlan::remap`] makes the worker count an
//! elastic runtime knob: a checkpoint trained at n workers
//! warm-starts and serves at m (`SessionBuilder::workers`, the
//! `pol reshard` CLI, `MulticoreTrainer::resume_source`) — flat
//! centralized tables predict bit-identically at any count, and tree
//! leaf tables are re-keyed weight-exactly (`n→m→n` is the identity).
//! See `examples/elastic_train.rs` for the full
//! train-4 → resume-8 → shrink-2 story under live serving.
//!
//! ## Three-layer architecture (+ the serving layer)
//!
//! * **L3 (this crate)** — the coordinator: data pipeline, feature
//!   hashing + sharding, node topologies, a simulated-network layer with
//!   a virtual clock, every update rule, metrics, the CLI, and the
//!   benches. Pure `std`: nodes are threads, links are `mpsc` channels
//!   with a latency/bandwidth model.
//! * **L2 (python/compile/model.py)** — the jax model: the per-node
//!   online sweep, the master combine step, and the minibatch-CG step,
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the per-node
//!   hot spot, `interpret=True`, checked against a pure-jnp oracle.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts via PJRT (the `xla` crate, behind the `pjrt` cargo
//! feature; the default build stubs it) at startup and serves them from
//! dedicated executor threads.
//!
//! On top of L3 sits **[`serve`]**, the production half: versioned
//! `.polz` checkpoints (format v2 adds zero-run compression and atomic
//! background writes) that round-trip any trained topology
//! bit-identically and warm-start training, plus multi-model
//! train-while-serve — a [`serve::ModelRegistry`] of named
//! [`serve::SnapshotCell`]s behind one [`serve::PredictionServer`], so
//! several architectures serve side by side with per-model
//! staleness/latency/QPS metrics while their trainers keep publishing.
//! See `pol train --checkpoint-every`, `pol serve` (repeatable
//! `--model name=path`), and `pol predict` in the CLI,
//! `benches/serve_throughput.rs`, and `examples/train_while_serve.rs`.
//!
//! ## Serving over the network
//!
//! **[`wire`]** turns the registry into a deployable service: a
//! versioned length-prefixed binary protocol (magic, op code, request
//! id, FNV checksum, strict caps — the frame layout table lives in the
//! [`wire`] module docs), a [`wire::WireServer`] whose bounded handler
//! pool drives the *same* registry/snapshot read path as the
//! in-process server (answers are bit-identical by construction), and
//! a blocking [`wire::WireClient`] with batched and pipelined predict
//! calls — the paper's §0.5.3 small-packet lesson applied to serving:
//! many predictions per frame, one checksum, one syscall each way.
//! An admin plane (`Stats`, `ListModels`, `Ping`, `Shutdown`) rides
//! the same protocol.
//!
//! ```no_run
//! use std::sync::Arc;
//! use pol::prelude::*;
//!
//! let model = pol::model::load("model.polz").expect("load");
//! let registry =
//!     ModelRegistry::with_model("m", SnapshotCell::new(model.snapshot()));
//! let server = WireServer::bind(
//!     "0.0.0.0:7878",
//!     Arc::clone(&registry),
//!     WireConfig::default(),
//! )
//! .expect("bind");
//! let mut client = WireClient::connect("127.0.0.1:7878").expect("connect");
//! let resp = client.predict_for("m", &[(0, 1.0)]).expect("predict");
//! println!("{} ({} instances behind)", resp.preds[0], resp.staleness);
//! # server.shutdown();
//! ```
//!
//! At the CLI: `pol serve --model m.polz --listen 0.0.0.0:7878` serves
//! checkpoints over TCP, `pol predict --connect HOST:7878` queries
//! them, and `pol serve-stats --connect HOST:7878` reads the wire
//! stats; `examples/net_train_serve.rs` runs the full
//! train-while-serve-over-TCP story through a live re-shard.
//!
//! ## Observability
//!
//! **[`obs`]** is the telemetry layer: a global-free
//! [`obs::MetricsRegistry`] of atomic counters/gauges/histograms (the
//! trainer's observed per-update τ distribution, pending-feedback
//! depth, per-shard traffic, pipeline pool occupancy, serving
//! QPS/latency/staleness, wire frame counters) plus a bounded
//! [`obs::TraceRing`] of control-plane events (publishes, re-shards,
//! checkpoints, shutdowns). Everything exports through one versioned
//! text format, and a remote process scrapes it over the wire:
//!
//! ```no_run
//! use pol::obs::parse_exposition;
//! use pol::wire::WireClient;
//!
//! let mut client = WireClient::connect("127.0.0.1:7878").expect("connect");
//! let text = client.metrics_dump().expect("scrape");
//! for (series, value) in parse_exposition(&text).expect("parse") {
//!     println!("{series} = {value}");
//! }
//! ```
//!
//! At the CLI, `pol metrics --connect HOST:7878` is that one-shot
//! scrape and `pol top --connect HOST:7878` is the live terminal view
//! (QPS, staleness, τ p50/p99, shard heat).

// The whole crate is safe code except the kernel layer in `simd/`
// (bounds-check-elided gathers, the AVX2 tier, and the aligned-table
// slice views), where every site carries a per-site `#[allow]` plus a
// reasoned `pol-lint: allow(L007, ...)` waiver; lint rule L007
// mechanically rejects `unsafe` anywhere else in the crate.
#![deny(unsafe_code)]
// Every public item documents itself; the `pol lint` pass (see
// `analyze`) enforces the invariants the docs promise.
#![deny(missing_docs)]

/// `pol lint` — the static analysis pass enforcing the crate's
/// hand-kept invariants (see its module docs for the rule table).
pub mod analyze;
/// Run configuration: the canonical `key = value` config text.
pub mod config;
/// Tree coordinators — the paper's sharded architectures.
pub mod coordinator;
/// Datasets, instances, and the synthetic generators.
pub mod data;
/// Crate-wide error type and the `anyhow`-shaped helpers.
pub mod error;
/// Regret/accuracy evaluation (propositions, delay sweeps).
pub mod eval;
/// Feature hashing (FNV-1a) and digests.
pub mod hashing;
/// Online learners: SGD, delayed SGD, naive Bayes, tree nodes.
pub mod learner;
/// Sparse/dense linear-algebra hot-path primitives.
pub mod linalg;
/// Loss functions and their gradients.
pub mod loss;
/// Learning-rate schedules.
pub mod lr;
/// Progressive validation and training metrics.
pub mod metrics;
/// The [`model::Model`] trait and the [`model::Session`] builder.
pub mod model;
/// Simulated network links for the delay experiments.
pub mod net;
/// Unified telemetry: metrics registry, trace ring, exposition.
pub mod obs;
/// The deterministic xorshift RNG every experiment seeds from.
pub mod rng;
/// Accelerator runtime stubs (artifact registry, exec servers).
pub mod runtime;
/// Model serving: snapshots, registry, prediction server.
pub mod serve;
/// Feature sharding plans and elastic re-sharding.
pub mod sharding;
/// Runtime-dispatched SIMD kernels and aligned weight storage.
pub mod simd;
/// Instance sources and the background parse pipeline.
pub mod stream;
/// Tree topologies (flat, binary, custom arity).
pub mod topology;
/// The TCP front-end: framed protocol, server, client.
pub mod wire;

mod bytes;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::config::{RunConfig, UpdateRule};
    pub use crate::coordinator::multicore::MulticoreTrainer;
    pub use crate::coordinator::{Coordinator, TrainReport};
    pub use crate::data::instance::Instance;
    pub use crate::data::synth::{
        AdDisplayGen, AdversarialDupGen, RcvLikeGen, SynthConfig,
        WebspamLikeGen,
    };
    pub use crate::data::Dataset;
    pub use crate::hashing::FeatureHasher;
    pub use crate::learner::delayed::DelayedSgd;
    pub use crate::learner::naive_bayes::NaiveBayes;
    pub use crate::learner::node::NodeLearner;
    pub use crate::learner::sgd::Sgd;
    pub use crate::learner::OnlineLearner;
    pub use crate::loss::Loss;
    pub use crate::lr::LrSchedule;
    pub use crate::metrics::ProgressiveValidator;
    pub use crate::model::{Model, Session, SessionBuilder};
    pub use crate::net::{LinkSpec, SimNetwork};
    pub use crate::obs::{MetricsRegistry, Obs, TraceKind, TraceRing};
    pub use crate::rng::Rng;
    pub use crate::serve::{
        ModelRegistry, ModelSnapshot, PredictClient, PredictionServer,
        SnapshotCell, SnapshotPublisher,
    };
    pub use crate::sharding::{ShardKind, ShardMigration, ShardPlan};
    pub use crate::simd::AlignedTable;
    pub use crate::stream::{
        CacheSource, DatasetSource, InstanceSource, Pipeline, RcvLikeSource,
        VwTextSource, WebspamLikeSource,
    };
    pub use crate::topology::Topology;
    pub use crate::wire::{WireClient, WireConfig, WireError, WireServer};
}
