//! Metrics: progressive validation (Blum et al. 1999), test-set accuracy,
//! throughput counters, and simple timers.

use crate::loss::Loss;

/// Progressive validation: average of ℓ(ŷ_t, y_t) where ŷ_t is the
/// prediction made *just prior* to the update for instance t. The paper
/// reports progressive squared loss throughout (§0.5.3). "When data is
/// independent, this metric has deviations similar to the average loss
/// computed on held-out evaluation data."
#[derive(Clone, Debug)]
pub struct ProgressiveValidator {
    sum_sq: f64,
    sum_loss: f64,
    correct: u64,
    n: u64,
    loss: Loss,
}

impl Default for ProgressiveValidator {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgressiveValidator {
    /// A validator scoring under the default loss.
    pub fn new() -> Self {
        Self::with_loss(Loss::Squared)
    }

    /// A validator scoring under `loss`.
    pub fn with_loss(loss: Loss) -> Self {
        ProgressiveValidator { sum_sq: 0.0, sum_loss: 0.0, correct: 0, n: 0, loss }
    }

    /// Record a pre-update prediction and its label.
    #[inline]
    pub fn observe(&mut self, yhat: f64, y: f64) {
        let d = yhat - y;
        self.sum_sq += d * d;
        self.sum_loss += self.loss.value(yhat, y);
        if self.loss.decide(yhat) == y {
            self.correct += 1;
        }
        self.n += 1;
    }

    /// Mean squared error over observed predictions.
    pub fn mean_squared(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_sq / self.n as f64
        }
    }

    /// Mean of the configured loss.
    pub fn mean_loss(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_loss / self.n as f64
        }
    }

    /// 0/1 accuracy of the loss's decision rule.
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.correct as f64 / self.n as f64
        }
    }

    /// Number of examples scored.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Merge another validator (used when averaging per-shard losses for
    /// Fig 0.5(a)).
    pub fn merge(&mut self, other: &ProgressiveValidator) {
        self.sum_sq += other.sum_sq;
        self.sum_loss += other.sum_loss;
        self.correct += other.correct;
        self.n += other.n;
    }
}

/// Held-out evaluation of a fixed predictor.
pub fn test_metrics(
    loss: Loss,
    predict: impl Fn(&[crate::linalg::SparseFeat]) -> f64,
    test: &[crate::data::instance::Instance],
) -> (f64, f64) {
    let mut sum = 0.0;
    let mut correct = 0u64;
    for inst in test {
        let yhat = predict(&inst.features);
        sum += loss.value(yhat, inst.label);
        if loss.decide(yhat) == inst.label {
            correct += 1;
        }
    }
    let n = test.len().max(1) as f64;
    (sum / n, correct as f64 / n)
}

/// Wall-clock + item throughput counter.
#[derive(Debug)]
pub struct Throughput {
    start: std::time::Instant,
    /// Instances processed.
    pub items: u64,
    /// Feature values processed.
    pub features: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    /// Start a throughput clock at zero items.
    pub fn new() -> Self {
        Throughput { start: std::time::Instant::now(), items: 0, features: 0 }
    }

    #[inline]
    /// Record one instance carrying `features` feature values.
    pub fn tick(&mut self, features: usize) {
        self.items += 1;
        self.features += features as u64;
    }

    /// Wall time since construction.
    pub fn elapsed(&self) -> std::time::Duration {
        self.start.elapsed()
    }

    /// Instances per second since construction.
    pub fn items_per_sec(&self) -> f64 {
        self.items as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }

    /// Feature values per second since construction.
    pub fn features_per_sec(&self) -> f64 {
        self.features as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }
}

/// Log2-bucketed latency histogram for the serving path: bucket `i`
/// holds samples with `floor(log2(ns)) == i`, so quantiles are exact to
/// within a factor of 2 with zero allocation on the hot path. Cheap
/// enough for one histogram per serving thread; [`Self::merge`] folds
/// them for reporting.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { buckets: [0; 64], count: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    /// Record one latency sample.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    #[inline]
    /// Record one latency sample in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[bucket] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Largest recorded latency in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Total of every recorded sample (saturating).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// The raw per-bucket counts (bucket `i` holds samples with
    /// `floor(log2(ns)) == i`) — the same edges
    /// [`crate::obs::Histogram`] uses, so the two fold together
    /// without rebinning.
    pub fn bucket_counts(&self) -> &[u64; 64] {
        &self.buckets
    }

    /// Upper edge (ns) of the bucket containing quantile `q` ∈ [0, 1] —
    /// a ≤2× overestimate of the true quantile, capped at the observed
    /// max.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let upper = if i >= 63 { u64::MAX } else { (2u64 << i) - 1 };
                return upper.min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Fold another histogram in (per-thread → global reporting).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progressive_squared() {
        let mut pv = ProgressiveValidator::new();
        pv.observe(0.0, 1.0); // sq err 1
        pv.observe(1.0, 1.0); // sq err 0
        assert!((pv.mean_squared() - 0.5).abs() < 1e-12);
        assert_eq!(pv.count(), 2);
    }

    #[test]
    fn accuracy_squared_convention() {
        let mut pv = ProgressiveValidator::new();
        pv.observe(0.9, 1.0); // correct
        pv.observe(0.1, 1.0); // wrong
        pv.observe(0.2, 0.0); // correct
        assert!((pv.accuracy() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = ProgressiveValidator::new();
        let mut b = ProgressiveValidator::new();
        let mut c = ProgressiveValidator::new();
        for (yh, y) in [(0.1, 0.0), (0.8, 1.0), (0.4, 1.0), (0.6, 0.0)] {
            c.observe(yh, y);
        }
        a.observe(0.1, 0.0);
        a.observe(0.8, 1.0);
        b.observe(0.4, 1.0);
        b.observe(0.6, 0.0);
        a.merge(&b);
        assert!((a.mean_squared() - c.mean_squared()).abs() < 1e-12);
        assert_eq!(a.count(), c.count());
    }

    #[test]
    fn empty_is_zero() {
        let pv = ProgressiveValidator::new();
        assert_eq!(pv.mean_squared(), 0.0);
        assert_eq!(pv.accuracy(), 0.0);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.quantile_ns(0.5);
        // third sample (400ns) sits in bucket [256, 511]
        assert!((256..=511).contains(&p50), "p50 {p50}");
        // p99 lands in the max sample's bucket, capped at observed max
        let p99 = h.quantile_ns(0.99);
        assert!(p99 >= 65_536 && p99 <= 100_000, "p99 {p99}");
        assert_eq!(h.quantile_ns(1.0), 100_000);
    }

    #[test]
    fn histogram_merge_matches_combined() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut c = LatencyHistogram::new();
        for (i, ns) in [10u64, 1000, 50, 7000, 320, 99].iter().enumerate() {
            if i % 2 == 0 {
                a.record_ns(*ns);
            } else {
                b.record_ns(*ns);
            }
            c.record_ns(*ns);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile_ns(0.5), c.quantile_ns(0.5));
        assert_eq!(a.max_ns(), c.max_ns());
        assert!((a.mean_ns() - c.mean_ns()).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.quantile_ns(0.0), 0);
        assert_eq!(h.quantile_ns(1.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.sum_ns(), 0);
    }

    #[test]
    fn histogram_single_sample_every_quantile() {
        let mut h = LatencyHistogram::new();
        h.record_ns(777);
        // with one sample, every quantile is that sample (the bucket
        // upper edge is capped at the observed max)
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_ns(q), 777, "q {q}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum_ns(), 777);
    }

    #[test]
    fn histogram_zero_sample_lands_in_bucket_zero() {
        let mut h = LatencyHistogram::new();
        h.record_ns(0);
        assert_eq!(h.bucket_counts()[0], 1);
        assert_eq!(h.count(), 1);
        // quantile is the bucket-0 upper edge capped at the max (0)
        assert_eq!(h.quantile_ns(0.5), 0);
    }

    #[test]
    fn histogram_all_in_overflow_bucket() {
        let mut h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record_ns(u64::MAX);
        }
        assert_eq!(h.bucket_counts()[63], 10);
        // the i >= 63 edge would be u64::MAX; the cap keeps it honest
        assert_eq!(h.quantile_ns(0.5), u64::MAX);
        assert_eq!(h.quantile_ns(0.99), u64::MAX);
        assert_eq!(h.max_ns(), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_monotone_under_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for ns in [50u64, 300, 1200, 90_000] {
            a.record_ns(ns);
        }
        for ns in [10u64, 10, 10, 2_000_000] {
            b.record_ns(ns);
        }
        for h in [&a, &b] {
            // p50 ≤ p99 ≤ p100 within each histogram
            assert!(h.quantile_ns(0.5) <= h.quantile_ns(0.99));
            assert!(h.quantile_ns(0.99) <= h.quantile_ns(1.0));
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert!(merged.quantile_ns(0.5) <= merged.quantile_ns(0.99));
        // merged extremes bracket the inputs' extremes
        assert_eq!(
            merged.quantile_ns(1.0),
            a.quantile_ns(1.0).max(b.quantile_ns(1.0))
        );
        assert!(
            merged.quantile_ns(0.0)
                <= a.quantile_ns(0.0).min(b.quantile_ns(0.0))
        );
        assert_eq!(merged.count(), a.count() + b.count());
    }
}
