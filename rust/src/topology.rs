//! Node topologies for multinode feature sharding (§0.5.2).
//!
//! * [`Topology::TwoLayer`] — Figure 0.2 / Figure 0.4: k feature shards
//!   feeding one master ("flat hierarchy", the configuration of the
//!   paper's experiments).
//! * [`Topology::BinaryTree`] — Figure 0.3: each leaf owns one feature
//!   shard; each internal node combines two subordinate predictions.
//! * [`Topology::KAry`] — the in-between the paper mentions ("somewhere
//!   in between the binary tree and the two-layer scheme"): fan-in k.
//!
//! [`NodeGraph`] is the resolved structure: parent/child arrays, the
//! leaf list (in shard order), and per-node depth. Internal node ids
//! come after leaf ids; the root is always the last id.

/// Declarative topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// One master over `shards` leaf workers.
    TwoLayer { shards: usize },
    /// Balanced binary reduction tree over `leaves` workers.
    BinaryTree { leaves: usize },
    /// K-ary reduction tree: `leaves` workers, `fanin` children per internal node.
    KAry { leaves: usize, fanin: usize },
}

impl Topology {
    /// Number of leaf (worker) nodes.
    pub fn leaves(&self) -> usize {
        match *self {
            Topology::TwoLayer { shards } => shards,
            Topology::BinaryTree { leaves } => leaves,
            Topology::KAry { leaves, .. } => leaves,
        }
    }

    /// The same topology kind resized to `leaves` workers (k-ary keeps
    /// its fan-in). This is the one place worker-count resizing matches
    /// on topology kind — the CLI, the session builder, and elastic
    /// re-sharding all call it instead of branching themselves.
    pub fn with_leaves(&self, leaves: usize) -> Topology {
        let leaves = leaves.max(1);
        match *self {
            Topology::TwoLayer { .. } => Topology::TwoLayer { shards: leaves },
            Topology::BinaryTree { .. } => Topology::BinaryTree { leaves },
            Topology::KAry { fanin, .. } => Topology::KAry { leaves, fanin },
        }
    }

    /// Short name of the topology kind.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Topology::TwoLayer { .. } => "two-layer",
            Topology::BinaryTree { .. } => "binary-tree",
            Topology::KAry { .. } => "kary",
        }
    }

    /// Materialise the node graph (parents, children, root).
    pub fn build(&self) -> NodeGraph {
        match *self {
            Topology::TwoLayer { shards } => NodeGraph::karyfrom(shards, shards),
            Topology::BinaryTree { leaves } => NodeGraph::karyfrom(leaves, 2),
            Topology::KAry { leaves, fanin } => NodeGraph::karyfrom(leaves, fanin),
        }
    }
}

/// Resolved node graph. Leaves are ids `0..leaves`; internal nodes are
/// built bottom-up layer by layer; `root` is the final combiner.
#[derive(Clone, Debug)]
pub struct NodeGraph {
    /// Parent of each node (`None` for the root).
    pub parent: Vec<Option<usize>>,
    /// Children of each node.
    pub children: Vec<Vec<usize>>,
    /// Number of leaf nodes.
    pub leaves: usize,
    /// Root node id.
    pub root: usize,
}

impl NodeGraph {
    fn karyfrom(leaves: usize, fanin: usize) -> NodeGraph {
        assert!(leaves >= 1 && fanin >= 2 || leaves == 1);
        let mut parent: Vec<Option<usize>> = vec![None; leaves];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); leaves];
        let mut layer: Vec<usize> = (0..leaves).collect();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(fanin));
            for group in layer.chunks(fanin) {
                let id = parent.len();
                parent.push(None);
                children.push(group.to_vec());
                for &c in group {
                    parent[c] = Some(id);
                }
                next.push(id);
            }
            layer = next;
        }
        // single leaf: add a master above it anyway (the paper's shard
        // count = 1 configuration still has a final output node)
        if leaves == 1 && parent.len() == 1 {
            parent.push(None);
            children.push(vec![0]);
            parent[0] = Some(1);
        }
        let root = parent.len() - 1;
        NodeGraph { parent, children, leaves, root }
    }

    /// Total node count.
    pub fn num_nodes(&self) -> usize {
        self.parent.len()
    }

    /// Whether `id` is a leaf.
    pub fn is_leaf(&self, id: usize) -> bool {
        id < self.leaves
    }

    /// Depth of a node (root = 0).
    pub fn depth(&self, mut id: usize) -> usize {
        let mut d = 0;
        while let Some(p) = self.parent[id] {
            id = p;
            d += 1;
        }
        d
    }

    /// Height of the tree = max leaf depth — the prediction latency in
    /// hops (the paper: O(log n) for the binary tree).
    pub fn height(&self) -> usize {
        (0..self.leaves).map(|l| self.depth(l)).max().unwrap_or(0)
    }

    /// Nodes in bottom-up evaluation order (children before parents) —
    /// valid because internal ids are assigned layer by layer.
    pub fn bottom_up(&self) -> impl Iterator<Item = usize> {
        0..self.num_nodes()
    }

    /// Nodes in top-down (feedback) order.
    pub fn top_down(&self) -> impl Iterator<Item = usize> {
        (0..self.num_nodes()).rev()
    }

    /// The set of leaf descendants of a node (the S_i of §0.5.2).
    pub fn leaf_descendants(&self, id: usize) -> Vec<usize> {
        if self.is_leaf(id) {
            return vec![id];
        }
        let mut out = Vec::new();
        let mut stack = vec![id];
        while let Some(n) = stack.pop() {
            if self.is_leaf(n) {
                out.push(n);
            } else {
                stack.extend(&self.children[n]);
            }
        }
        out.sort();
        out
    }

    /// Max fan-in over internal nodes — each internal node "may incur
    /// delay proportional to its fan-in" (§0.5.2).
    pub fn max_fanin(&self) -> usize {
        self.children.iter().map(Vec::len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_layer_shape() {
        let g = Topology::TwoLayer { shards: 8 }.build();
        assert_eq!(g.num_nodes(), 9);
        assert_eq!(g.root, 8);
        assert_eq!(g.children[8].len(), 8);
        assert_eq!(g.height(), 1);
        for l in 0..8 {
            assert_eq!(g.parent[l], Some(8));
        }
    }

    #[test]
    fn binary_tree_shape() {
        let g = Topology::BinaryTree { leaves: 8 }.build();
        // 8 + 4 + 2 + 1
        assert_eq!(g.num_nodes(), 15);
        assert_eq!(g.height(), 3);
        assert_eq!(g.max_fanin(), 2);
        assert_eq!(g.leaf_descendants(g.root), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn binary_tree_non_power_of_two() {
        let g = Topology::BinaryTree { leaves: 5 }.build();
        assert_eq!(g.leaves, 5);
        // all leaves reachable from root
        assert_eq!(g.leaf_descendants(g.root).len(), 5);
        // bottom-up order property: children precede parents
        for id in 0..g.num_nodes() {
            for &c in &g.children[id] {
                assert!(c < id);
            }
        }
    }

    #[test]
    fn single_shard_still_has_master() {
        let g = Topology::TwoLayer { shards: 1 }.build();
        assert_eq!(g.num_nodes(), 2);
        assert_eq!(g.root, 1);
        assert!(g.is_leaf(0));
    }

    #[test]
    fn kary_heights() {
        let g4 = Topology::KAry { leaves: 16, fanin: 4 }.build();
        assert_eq!(g4.height(), 2);
        let g2 = Topology::KAry { leaves: 16, fanin: 2 }.build();
        assert_eq!(g2.height(), 4);
    }

    #[test]
    fn with_leaves_keeps_kind_and_fanin() {
        assert_eq!(
            Topology::TwoLayer { shards: 4 }.with_leaves(9),
            Topology::TwoLayer { shards: 9 }
        );
        assert_eq!(
            Topology::BinaryTree { leaves: 8 }.with_leaves(3),
            Topology::BinaryTree { leaves: 3 }
        );
        assert_eq!(
            Topology::KAry { leaves: 16, fanin: 4 }.with_leaves(8),
            Topology::KAry { leaves: 8, fanin: 4 }
        );
        // a zero request clamps to the minimum viable worker count
        assert_eq!(
            Topology::TwoLayer { shards: 4 }.with_leaves(0),
            Topology::TwoLayer { shards: 1 }
        );
    }

    #[test]
    fn leaf_descendants_partition() {
        let g = Topology::BinaryTree { leaves: 8 }.build();
        // the two children of the root partition the leaves
        let cs = &g.children[g.root];
        let mut all: Vec<usize> = cs
            .iter()
            .flat_map(|&c| g.leaf_descendants(c))
            .collect();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }
}
