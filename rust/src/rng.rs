//! Deterministic, seedable RNG used everywhere in the crate.
//!
//! All randomness in `pol` flows through [`Rng`] instances owned by each
//! component (generator, shuffler, initializer), so a run is a pure
//! function of its config — the §0.6.6 determinism requirement. The
//! generator is splitmix64-seeded xoshiro256++, which is small, fast,
//! and has no external dependency (the environment ships no `rand`).

/// xoshiro256++ with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    /// Next pseudo-random 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // widening-multiply rejection-free mapping (Lemire); tiny bias
        // acceptable for data synthesis, not for crypto.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// determinism simplicity).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Zipf-like rank sample over [0, n): P(k) ∝ 1/(k+1)^s, via inverse
    /// CDF on a precomputed table is overkill here; we use the standard
    /// rejection-inversion-free approximation adequate for synthetic
    /// power-law feature draws.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-transform on the continuous Pareto then clamp
        let u = self.next_f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let k = ((n as f64).powf(u) - 1.0).floor() as u64;
            k.min(n - 1)
        } else {
            let t = 1.0 - s;
            let k = (((n as f64).powf(t) - 1.0) * u + 1.0).powf(1.0 / t) - 1.0;
            (k.floor() as u64).min(n - 1)
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let m: f64 = (0..100_000).map(|_| r.next_f64()).sum::<f64>() / 1e5;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(11);
        let mut counts = vec![0u64; 100];
        for _ in 0..100_000 {
            let k = r.zipf(100, 1.1) as usize;
            assert!(k < 100);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[50], "head {} tail {}", counts[0], counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(50, 10);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
