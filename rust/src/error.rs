//! Minimal error plumbing standing in for `anyhow` (the environment
//! ships no external crates, so the runtime and serve layers use this
//! message-carrying error type instead).
//!
//! The API mirrors the `anyhow` subset the crate uses: a string-holding
//! [`Error`], a defaulted [`Result`] alias, the [`Context`] extension
//! trait for `Result`/`Option`, and the [`format_err!`] macro (imported
//! `as anyhow` at call sites that were written against `anyhow!`).

use std::fmt;

/// A boxed, message-carrying error. Context frames are prepended
/// `outer: inner` exactly like `anyhow`'s `{:#}` chain rendering.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }

    /// A poisoned-lock error: some thread panicked while holding the
    /// named lock. The typed counterpart to `lock().unwrap()` — callers
    /// that cannot safely recover a poisoned guard (see [`LockExt`])
    /// surface this instead of cascading the panic.
    pub fn poisoned(what: &str) -> Self {
        Error {
            msg: format!(
                "{what}: lock poisoned (a thread panicked while holding it)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error { msg: m }
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error { msg: m.to_string() }
    }
}

/// Crate-wide result alias (defaulted error type, like `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` equivalent for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context(self, c: impl fmt::Display) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Disciplined handling of [`std::sync::PoisonError`] lock results —
/// the crate-wide replacement for `lock().unwrap()` (lint rule L001).
///
/// Two recovery postures, chosen per call site:
/// - [`LockExt::or_poisoned`] maps poison to a typed [`Error`]; use it
///   where the caller has a `Result` surface and the guarded data may
///   be mid-mutation when a holder panics.
/// - [`LockExt::recover_poisoned`] takes the guard anyway; use it ONLY
///   where every critical section leaves the data valid at all times
///   (monotonic counters, whole-`Arc` slot swaps, append-only maps),
///   and say so in a comment at the call site.
pub trait LockExt<G> {
    /// The guard, or a typed [`Error`] naming `what` if the lock was
    /// poisoned by a panicking holder.
    fn or_poisoned(self, what: &str) -> Result<G>;

    /// The guard regardless of poisoning. Sound only when the protected
    /// data is valid after any partial critical section.
    fn recover_poisoned(self) -> G;
}

impl<G> LockExt<G> for std::result::Result<G, std::sync::PoisonError<G>> {
    fn or_poisoned(self, what: &str) -> Result<G> {
        self.map_err(|_| Error::poisoned(what))
    }

    fn recover_poisoned(self) -> G {
        self.unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// `anyhow!`-shaped constructor: `format_err!("bad {x}")` → [`Error`].
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_err_formats() {
        let e = format_err!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn context_on_result() {
        let r: std::result::Result<(), std::io::Error> = Err(
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "), "{e}");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn lock_ext_types_and_recovers_poison() {
        use std::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let e = m.lock().or_poisoned("test lock").unwrap_err();
        assert!(e.to_string().contains("lock poisoned"), "{e}");
        // the data is a plain counter: recovery is sound
        assert_eq!(*m.lock().recover_poisoned(), 7);
    }

    #[test]
    fn alternate_format_is_plain_message() {
        // call sites render errors with {e:#}; our single-frame chain
        // prints the same string either way
        let e = format_err!("outer").context("inner-ctx");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
