//! Minimal error plumbing standing in for `anyhow` (the environment
//! ships no external crates, so the runtime and serve layers use this
//! message-carrying error type instead).
//!
//! The API mirrors the `anyhow` subset the crate uses: a string-holding
//! [`Error`], a defaulted [`Result`] alias, the [`Context`] extension
//! trait for `Result`/`Option`, and the [`format_err!`] macro (imported
//! `as anyhow` at call sites that were written against `anyhow!`).

use std::fmt;

/// A boxed, message-carrying error. Context frames are prepended
/// `outer: inner` exactly like `anyhow`'s `{:#}` chain rendering.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error { msg: m }
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error { msg: m.to_string() }
    }
}

/// Crate-wide result alias (defaulted error type, like `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` equivalent for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context(self, c: impl fmt::Display) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`-shaped constructor: `format_err!("bad {x}")` → [`Error`].
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_err_formats() {
        let e = format_err!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn context_on_result() {
        let r: std::result::Result<(), std::io::Error> = Err(
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "), "{e}");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn alternate_format_is_plain_message() {
        // call sites render errors with {e:#}; our single-frame chain
        // prints the same string either way
        let e = format_err!("outer").context("inner-ctx");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
