//! Learning-rate schedules.
//!
//! The paper's experiments search schedules of the form
//! η_t = λ / √(t + t₀) with λ ∈ {2^i}_{i=0..9}, t₀ ∈ {10^i}_{i=0..6}
//! (§0.7), plus the delay-aware rates of Theorem 1:
//! η_t = R/(L√(2τt)) (adversarial) and η_t = 1/(c(t−τ)) (strongly
//! convex).

/// A learning-rate schedule η_t, with t counted from 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// η_t = λ (constant)
    Constant { lambda: f64 },
    /// η_t = λ / √(t + t₀)  — the paper's search family (§0.7)
    InvSqrt { lambda: f64, t0: f64 },
    /// η_t = λ / (t + t₀)   — strongly-convex rate (Theorem 1, c folded
    /// into λ; the τ offset folded into t₀)
    Inv { lambda: f64, t0: f64 },
    /// η_t = R / (L √(2 τ t)) — Theorem 1's adversarial delayed rate
    DelayedAdversarial { r: f64, l: f64, tau: f64 },
}

impl LrSchedule {
    /// Constant rate `lambda`.
    pub fn constant(lambda: f64) -> Self {
        LrSchedule::Constant { lambda }
    }

    /// `lambda / sqrt(t + t0)` decay.
    pub fn inv_sqrt(lambda: f64, t0: f64) -> Self {
        LrSchedule::InvSqrt { lambda, t0 }
    }

    /// `lambda / (t + t0)` decay (strongly-convex rate).
    pub fn inv(lambda: f64, t0: f64) -> Self {
        LrSchedule::Inv { lambda, t0 }
    }

    /// Theorem 1's adversarial delayed rate `R / (L * sqrt(2 * tau * t))`.
    pub fn delayed_adversarial(r: f64, l: f64, tau: f64) -> Self {
        LrSchedule::DelayedAdversarial { r, l, tau: tau.max(1.0) }
    }

    /// η at step t (t ≥ 1).
    #[inline]
    pub fn eta(&self, t: u64) -> f64 {
        let tf = t as f64;
        match *self {
            LrSchedule::Constant { lambda } => lambda,
            LrSchedule::InvSqrt { lambda, t0 } => lambda / (tf + t0).sqrt(),
            LrSchedule::Inv { lambda, t0 } => lambda / (tf + t0),
            LrSchedule::DelayedAdversarial { r, l, tau } => {
                r / (l * (2.0 * tau * tf).sqrt())
            }
        }
    }

    /// Compact machine-parseable spec: `const:λ`, `invsqrt:λ:t0`,
    /// `inv:λ:t0`, `delayed:R:L:τ`. Used by config files and the
    /// checkpoint format; round-trips through [`Self::parse_spec`].
    pub fn spec(&self) -> String {
        match *self {
            LrSchedule::Constant { lambda } => format!("const:{lambda}"),
            LrSchedule::InvSqrt { lambda, t0 } => format!("invsqrt:{lambda}:{t0}"),
            LrSchedule::Inv { lambda, t0 } => format!("inv:{lambda}:{t0}"),
            LrSchedule::DelayedAdversarial { r, l, tau } => {
                format!("delayed:{r}:{l}:{tau}")
            }
        }
    }

    /// Parse a [`Self::spec`] string.
    pub fn parse_spec(s: &str) -> Option<LrSchedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize| -> Option<f64> { parts.get(i)?.parse().ok() };
        match (parts.first().copied()?, parts.len()) {
            ("const", 2) => Some(LrSchedule::Constant { lambda: num(1)? }),
            ("invsqrt", 3) => {
                Some(LrSchedule::InvSqrt { lambda: num(1)?, t0: num(2)? })
            }
            ("inv", 3) => Some(LrSchedule::Inv { lambda: num(1)?, t0: num(2)? }),
            ("delayed", 4) => Some(LrSchedule::DelayedAdversarial {
                r: num(1)?,
                l: num(2)?,
                tau: num(3)?,
            }),
            _ => None,
        }
    }

    /// The paper's §0.7 grid: λ ∈ {2^0..2^9} × t₀ ∈ {10^0..10^6}.
    pub fn paper_grid() -> Vec<LrSchedule> {
        let mut out = Vec::with_capacity(70);
        for i in 0..10 {
            for j in 0..7 {
                out.push(LrSchedule::inv_sqrt(
                    (1u64 << i) as f64,
                    10f64.powi(j),
                ));
            }
        }
        out
    }

    /// A small sub-grid for fast tests/benches (same family).
    pub fn small_grid() -> Vec<LrSchedule> {
        let mut out = Vec::new();
        for &lambda in &[0.25, 1.0, 4.0] {
            for &t0 in &[1.0, 100.0, 10_000.0] {
                out.push(LrSchedule::inv_sqrt(lambda, t0));
            }
        }
        out
    }
}

impl std::fmt::Display for LrSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            LrSchedule::Constant { lambda } => write!(f, "const({lambda})"),
            LrSchedule::InvSqrt { lambda, t0 } => {
                write!(f, "{lambda}/sqrt(t+{t0})")
            }
            LrSchedule::Inv { lambda, t0 } => write!(f, "{lambda}/(t+{t0})"),
            LrSchedule::DelayedAdversarial { r, l, tau } => {
                write!(f, "{r}/({l}*sqrt(2*{tau}*t))")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_sqrt_decreasing() {
        let s = LrSchedule::inv_sqrt(1.0, 1.0);
        assert!(s.eta(1) > s.eta(10));
        assert!(s.eta(10) > s.eta(1000));
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.3);
        assert_eq!(s.eta(1), s.eta(1_000_000));
    }

    #[test]
    fn paper_grid_size() {
        assert_eq!(LrSchedule::paper_grid().len(), 70);
    }

    #[test]
    fn delayed_rate_scales_inverse_sqrt_tau() {
        let s1 = LrSchedule::delayed_adversarial(1.0, 1.0, 1.0);
        let s4 = LrSchedule::delayed_adversarial(1.0, 1.0, 4.0);
        let ratio = s1.eta(100) / s4.eta(100);
        assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn spec_roundtrip() {
        for s in [
            LrSchedule::constant(0.25),
            LrSchedule::inv_sqrt(2.0, 100.0),
            LrSchedule::inv(1.5, 7.0),
            LrSchedule::delayed_adversarial(1.0, 2.0, 64.0),
        ] {
            assert_eq!(LrSchedule::parse_spec(&s.spec()), Some(s), "{}", s.spec());
        }
        assert_eq!(LrSchedule::parse_spec("nope"), None);
        assert_eq!(LrSchedule::parse_spec("invsqrt:1"), None);
    }

    #[test]
    fn eta_positive_finite() {
        for s in LrSchedule::paper_grid() {
            for t in [1u64, 7, 1_000_000] {
                let e = s.eta(t);
                assert!(e.is_finite() && e > 0.0);
            }
        }
    }
}
