//! [`WireClient`] — the blocking client half of the wire protocol.
//!
//! One client owns one persistent connection (connection reuse is the
//! point: the TCP + frame overhead amortizes over every request, the
//! paper's small-packet lesson). Requests are answered in order;
//! [`WireClient::predict_pipelined`] overlaps many in-flight frames on
//! the one connection and matches responses back by request id. All
//! failures are a typed [`WireError`] — transport, framing, or a typed
//! error frame from the server — never a hang on a well-behaved
//! socket, never a panic.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::linalg::SparseFeat;
use crate::obs::SeriesSnapshot;
use crate::wire::frame::{
    decode_history, decode_models, decode_predict_response, decode_stats,
    put_instance, put_name, put_u32, read_frame, status_name, Frame,
    FrameBuf, FrameError, FrameWriter, ModelEntry, Op, StatsReport,
    MAX_BATCH, MAX_NAME, MAX_PING, STATUS_OK,
};

/// Why a wire call failed.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (connect, read, write).
    Io(io::Error),
    /// The peer sent bytes that do not decode as a valid frame.
    Frame(FrameError),
    /// The server answered with a typed error frame.
    Server { status: u8, message: String },
    /// The connection closed where a response was expected.
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::Frame(e) => write!(f, "wire protocol: {e}"),
            WireError::Server { status, message } => write!(
                f,
                "server error ({}): {message}",
                status_name(*status)
            ),
            WireError::Closed => write!(f, "connection closed by server"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

impl From<FrameError> for WireError {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Io(e) => WireError::Io(e),
            other => WireError::Frame(other),
        }
    }
}

/// One answered predict call.
#[derive(Clone, Debug)]
pub struct WireResponse {
    /// One prediction per submitted row.
    pub preds: Vec<f64>,
    /// Version of the snapshot that answered.
    pub snapshot_version: u64,
    /// Instances the trainer was ahead of that snapshot.
    pub staleness: u64,
}

/// Blocking client over one reused TCP connection (see module docs).
pub struct WireClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    buf: FrameBuf,
    out: FrameWriter,
    next_id: u64,
}

impl WireClient {
    /// Connect to a [`crate::wire::WireServer`] (or anything speaking
    /// the frame protocol).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<WireClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone()?;
        Ok(WireClient {
            reader: BufReader::with_capacity(1 << 16, stream),
            writer: BufWriter::with_capacity(1 << 16, write_half),
            buf: FrameBuf::new(),
            out: FrameWriter::new(),
            next_id: 1,
        })
    }

    fn check_name(model: &str) -> Result<(), WireError> {
        if model.len() > MAX_NAME {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("model name {} bytes (cap {MAX_NAME})", model.len()),
            )));
        }
        Ok(())
    }

    /// Start a request frame; returns its id.
    fn begin(&mut self, op: Op) -> u64 {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        // pol-lint: allow(L006, "Op discriminants are u8 by definition")
        self.out.start(op as u8, 0, id);
        id
    }

    /// Seal and write the frame under construction (no flush — callers
    /// flush once per send window).
    fn enqueue(&mut self) -> Result<(), WireError> {
        self.out.finish_to(&mut self.writer)?;
        Ok(())
    }

    fn flush(&mut self) -> Result<(), WireError> {
        self.writer.flush()?;
        Ok(())
    }

    /// Read the response to `(op, req_id)`. A non-OK status becomes
    /// [`WireError::Server`] (whatever its id: a draining server tags
    /// its final frame with id 0); an id/op mismatch on an OK frame is
    /// a protocol error.
    fn recv_expect(
        &mut self,
        op: Op,
        req_id: u64,
    ) -> Result<&[u8], WireError> {
        let frame: Frame<'_> =
            match read_frame(&mut self.reader, &mut self.buf, None, None)? {
                Some(f) => f,
                None => return Err(WireError::Closed),
            };
        if frame.status != STATUS_OK {
            // this request's own error frame, or a connection-wide
            // drain notice (a draining server tags its final frame
            // with id 0); an error frame for a *different* request is
            // a desynced stream, not this request's answer
            if frame.req_id == req_id || frame.req_id == 0 {
                return Err(WireError::Server {
                    status: frame.status,
                    message: String::from_utf8_lossy(frame.payload)
                        .into_owned(),
                });
            }
            return Err(WireError::Frame(FrameError::BadPayload(
                "response does not match the request id/op",
            )));
        }
        // pol-lint: allow(L006, "Op discriminants are u8 by definition")
        if frame.op != op as u8 || frame.req_id != req_id {
            return Err(WireError::Frame(FrameError::BadPayload(
                "response does not match the request id/op",
            )));
        }
        Ok(frame.payload)
    }

    /// Read and discard one response frame whatever its status — used
    /// to resynchronize the connection after a mid-pipeline failure.
    fn discard_response(&mut self) -> Result<(), WireError> {
        match read_frame(&mut self.reader, &mut self.buf, None, None)? {
            Some(_) => Ok(()),
            None => Err(WireError::Closed),
        }
    }

    /// Send one `Predict` frame (no flush, no read); returns its id.
    fn enqueue_predict(
        &mut self,
        model: &str,
        x: &[SparseFeat],
    ) -> Result<u64, WireError> {
        let id = self.begin(Op::Predict);
        {
            let p = self.out.payload();
            put_name(p, model);
        }
        put_instance(self.out.payload(), x)?;
        self.enqueue()?;
        Ok(id)
    }

    /// Read + validate one `Predict` response (exactly one prediction —
    /// a peer answering with another count is a protocol error, so
    /// `preds[0]` is always safe on a returned response).
    fn read_predict_response(
        &mut self,
        id: u64,
    ) -> Result<WireResponse, WireError> {
        let mut preds = Vec::with_capacity(1);
        let payload = self.recv_expect(Op::Predict, id)?;
        let (snapshot_version, staleness) =
            decode_predict_response(payload, &mut preds)?;
        if preds.len() != 1 {
            return Err(WireError::Frame(FrameError::BadPayload(
                "predict response must carry exactly one prediction",
            )));
        }
        Ok(WireResponse { preds, snapshot_version, staleness })
    }

    /// Score one instance against the named model.
    pub fn predict_for(
        &mut self,
        model: &str,
        x: &[SparseFeat],
    ) -> Result<WireResponse, WireError> {
        Self::check_name(model)?;
        let id = self.enqueue_predict(model, x)?;
        self.flush()?;
        self.read_predict_response(id)
    }

    /// Score a batch in ONE frame — the small-packet fix: n predictions
    /// amortize one header, one checksum, one syscall each way.
    pub fn predict_batch_for(
        &mut self,
        model: &str,
        batch: &[Vec<SparseFeat>],
    ) -> Result<WireResponse, WireError> {
        let mut preds = Vec::with_capacity(batch.len());
        let (snapshot_version, staleness) =
            self.predict_batch_into(model, batch, &mut preds)?;
        Ok(WireResponse { preds, snapshot_version, staleness })
    }

    /// [`Self::predict_batch_for`] into a caller-owned buffer — the
    /// zero-allocation steady-state path; returns
    /// `(snapshot_version, staleness)`.
    pub fn predict_batch_into(
        &mut self,
        model: &str,
        batch: &[Vec<SparseFeat>],
        preds: &mut Vec<f64>,
    ) -> Result<(u64, u64), WireError> {
        Self::check_name(model)?;
        if batch.len() as u64 > MAX_BATCH as u64 {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "batch of {} instances (wire cap {MAX_BATCH})",
                    batch.len()
                ),
            )));
        }
        let id = self.begin(Op::PredictBatch);
        {
            let p = self.out.payload();
            put_name(p, model);
            // pol-lint: allow(L006, "batch len checked against MAX_BATCH above")
            put_u32(p, batch.len() as u32);
        }
        for x in batch {
            put_instance(self.out.payload(), x)?;
        }
        self.enqueue()?;
        self.flush()?;
        let payload = self.recv_expect(Op::PredictBatch, id)?;
        let meta = decode_predict_response(payload, preds)?;
        if preds.len() != batch.len() {
            return Err(WireError::Frame(FrameError::BadPayload(
                "batch response prediction count does not match the request",
            )));
        }
        Ok(meta)
    }

    /// In-flight frames [`Self::predict_pipelined`] keeps outstanding
    /// before reading a response. Bounded so the responses queued
    /// behind an arbitrarily long request stream can never fill both
    /// peers' socket buffers and deadlock the connection.
    pub const PIPELINE_WINDOW: usize = 32;

    /// Pipelining: keep up to [`Self::PIPELINE_WINDOW`] `Predict`
    /// frames in flight on the one connection, collecting responses in
    /// order and checking each against its request id. Overlaps client
    /// send, server compute, and the wire — for any number of
    /// instances.
    ///
    /// On failure the *first* error is returned, and the responses
    /// still owed to other in-flight requests are read and discarded
    /// first, so the connection stays frame-synchronized and the
    /// client remains usable (unless the transport itself failed).
    pub fn predict_pipelined(
        &mut self,
        model: &str,
        instances: &[Vec<SparseFeat>],
    ) -> Result<Vec<WireResponse>, WireError> {
        Self::check_name(model)?;
        let mut responses = Vec::with_capacity(instances.len());
        let mut pending = std::collections::VecDeque::new();
        let mut error: Option<WireError> = None;
        for x in instances {
            if pending.len() >= Self::PIPELINE_WINDOW {
                // drain one slot before sending more: the window
                // bounds unread responses, so neither side's socket
                // buffer can fill up and stall the other
                if let Err(e) = self.flush() {
                    error = Some(e);
                    break;
                }
                let Some(id) = pending.pop_front() else { break };
                match self.read_predict_response(id) {
                    Ok(r) => responses.push(r),
                    Err(e) => {
                        error = Some(e);
                        break;
                    }
                }
            }
            match self.enqueue_predict(model, x) {
                Ok(id) => pending.push_back(id),
                Err(e) => {
                    error = Some(e);
                    break;
                }
            }
        }
        // flush unconditionally: every id in `pending` was enqueued,
        // and the resync drain below can only work if those frames
        // actually reached the server (enqueue failures never leave a
        // partial frame behind — the frame is only written whole)
        if let Err(e) = self.flush() {
            error.get_or_insert(e);
        }
        while let Some(id) = pending.pop_front() {
            if error.is_some() {
                // resynchronize: consume the frames still owed so the
                // next call on this client reads its own response
                if self.discard_response().is_err() {
                    break; // transport gone; nothing left to recover
                }
                continue;
            }
            match self.read_predict_response(id) {
                Ok(r) => responses.push(r),
                Err(e) => error = Some(e),
            }
        }
        match error {
            None => Ok(responses),
            Some(e) => Err(e),
        }
    }

    /// Liveness probe; the payload (≤ [`MAX_PING`] bytes) round-trips.
    pub fn ping(&mut self, payload: &[u8]) -> Result<Vec<u8>, WireError> {
        if payload.len() > MAX_PING {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("ping payload {} bytes (cap {MAX_PING})", payload.len()),
            )));
        }
        let id = self.begin(Op::Ping);
        self.out.payload().extend_from_slice(payload);
        self.enqueue()?;
        self.flush()?;
        let echoed = self.recv_expect(Op::Ping, id)?;
        Ok(echoed.to_vec())
    }

    /// Admin: wire-level + per-model serving stats.
    pub fn stats(&mut self) -> Result<StatsReport, WireError> {
        let id = self.begin(Op::Stats);
        self.enqueue()?;
        self.flush()?;
        let payload = self.recv_expect(Op::Stats, id)?;
        Ok(decode_stats(payload)?)
    }

    /// Admin: scrape the server's full metrics exposition — the
    /// versioned `# pol-metrics v1` text (see [`crate::obs`]), parseable
    /// with [`crate::obs::parse_exposition`]. The request carries no
    /// payload; the response is the text itself.
    pub fn metrics_dump(&mut self) -> Result<String, WireError> {
        let id = self.begin(Op::MetricsDump);
        self.enqueue()?;
        self.flush()?;
        let payload = self.recv_expect(Op::MetricsDump, id)?;
        String::from_utf8(payload.to_vec()).map_err(|_| {
            WireError::Frame(FrameError::BadPayload(
                "metrics dump payload is not UTF-8",
            ))
        })
    }

    /// Admin: the server's own metrics history — the tail of its
    /// bounded ring of periodic whole-registry snapshots, oldest
    /// first. Rates computed between adjacent snapshots
    /// ([`crate::obs::rate_per_sec`]) reflect the *server's* sampling
    /// cadence, not the scrape interval, so `pol top` renders true
    /// server-side rates from one request. Empty when the server runs
    /// without a sampler (`history_every: None`) or has not completed
    /// its first sampling period yet.
    pub fn metrics_history(
        &mut self,
    ) -> Result<Vec<SeriesSnapshot>, WireError> {
        let id = self.begin(Op::MetricsHistory);
        self.enqueue()?;
        self.flush()?;
        let payload = self.recv_expect(Op::MetricsHistory, id)?;
        Ok(decode_history(payload)?)
    }

    /// Admin: the registry's current models.
    pub fn list_models(&mut self) -> Result<Vec<ModelEntry>, WireError> {
        let id = self.begin(Op::ListModels);
        self.enqueue()?;
        self.flush()?;
        let payload = self.recv_expect(Op::ListModels, id)?;
        Ok(decode_models(payload)?)
    }

    /// Admin: ask the server to drain and stop. `Ok` means the server
    /// acknowledged and is draining; servers with remote shutdown
    /// disabled answer with a [`WireError::Server`] forbidden status.
    pub fn shutdown_server(&mut self) -> Result<(), WireError> {
        let id = self.begin(Op::Shutdown);
        self.enqueue()?;
        self.flush()?;
        self.recv_expect(Op::Shutdown, id)?;
        Ok(())
    }
}
