//! [`WireServer`] — the TCP front-end over the serving registry.
//!
//! Two I/O backends answer the same protocol behind one handle,
//! selected by [`WireConfig::io_model`] (see [`crate::wire`] for the
//! when-to-pick-which discussion):
//!
//! * [`IoModel::Threads`] — one `std::net::TcpListener` acceptor
//!   thread feeds accepted connections to a **bounded** pool of
//!   handler threads (the pool size is the concurrency cap; further
//!   connections queue in the kernel accept backlog — a connection
//!   flood cannot spawn unbounded threads).
//! * [`IoModel::Poll`] — one readiness loop multiplexes every
//!   connection over nonblocking sockets (see [`crate::wire::poll`]):
//!   concurrency is capped by [`WireConfig::max_conns`] admission
//!   control instead of a thread count, overload sheds typed
//!   over-capacity frames, and a per-connection
//!   [`WireConfig::frame_budget`] keeps a chatty pipelining peer from
//!   starving the rest.
//!
//! Either way a connection is served with exactly the per-connection
//! state the in-process serving workers own per thread: a
//! [`ModelCache`] of `(reader, scratch)` pairs, a recycled
//! [`FrameBuf`]/[`FrameWriter`], and recycled decode/predict buffers —
//! the steady-state request path allocates nothing, and scoring drives
//! the *same* [`crate::serve::ModelRegistry`]/snapshot read path as
//! [`crate::serve::PredictionServer`] through one shared dispatch
//! ([`answer_frame`]), so wire answers are bit-identical to in-process
//! answers — and across the two backends — by construction.
//!
//! Requests pipeline: a client may send many frames without waiting;
//! the handler answers them in arrival order and every response
//! carries the request id it answers. Malformed *payloads* get typed
//! error frames; framing-level corruption (bad length, magic, version,
//! checksum, truncation) means the byte stream can no longer be
//! trusted, so the connection closes cleanly instead — either way a
//! hostile peer gets bounded allocation and no panic.
//!
//! Shutdown drains gracefully: [`WireServer::shutdown`] (or a
//! [`Op::Shutdown`] admin frame, when permitted) stops the acceptor,
//! lets every handler answer the frames already buffered on its
//! connection (bounded by [`DRAIN_FRAMES`]), then closes. Wire-level
//! totals (bytes/frames/decode errors) and per-model latency
//! histograms are readable live through [`WireServer::stats`] or
//! remotely via the [`Op::Stats`] admin op.

// Every Relaxed here is monotonic telemetry (byte/frame/connection
// counters, the active-handler gauge); cross-thread hand-off of real
// data goes through channels and mutexes, never through these atomics.
// pol-lint: allow-file(L002, "wire counters are monotonic telemetry")

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::LockExt;
use crate::obs::{
    names, Exposition, FlightRecord, HistogramSnapshot, Obs, Phase,
    PhaseSpans, SeriesRing, DEFAULT_SERIES_CAPACITY,
};
use crate::serve::registry::{ModelCache, ModelRegistry};
use crate::serve::server::ModelStats;
use crate::wire::frame::{
    decode_predict_request, put_history, put_models, put_predict_response,
    put_stats, read_frame, BatchScratch, FrameBuf, FrameError, FrameWriter,
    ModelEntry, ModelStatsReport, Op, StatsReport, MAX_HISTORY_SNAPSHOTS,
    MAX_PING, STATUS_BAD_FRAME, STATUS_FORBIDDEN, STATUS_OK,
    STATUS_SHUTTING_DOWN, STATUS_TOO_LARGE, STATUS_UNKNOWN_MODEL,
    STATUS_UNKNOWN_OP,
};

/// Frames a draining handler still answers before closing its
/// connection — bounded so a peer that keeps streaming cannot hold the
/// drain open forever.
pub const DRAIN_FRAMES: u32 = 256;

/// Default admission cap for the [`IoModel::Poll`] backend.
pub const DEFAULT_MAX_CONNS: usize = 1024;

/// Default [`WireConfig::frame_budget`] for the [`IoModel::Poll`]
/// backend.
pub const DEFAULT_FRAME_BUDGET: u32 = 16;

/// Which I/O backend [`WireServer::bind`] starts. Both speak the
/// identical protocol over the identical registry read path; they
/// differ only in how connections map onto threads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IoModel {
    /// Blocking I/O, one handler thread per active connection, pool
    /// bounded by [`WireConfig::handlers`]. Simple and fast for a few
    /// busy peers; concurrency is capped at the thread count.
    #[default]
    Threads,
    /// One readiness loop multiplexing every connection over
    /// nonblocking sockets. Concurrency is capped by
    /// [`WireConfig::max_conns`] (overload sheds typed frames instead
    /// of queueing), so thousands of mostly-idle peers cost no
    /// threads.
    Poll,
}

impl std::fmt::Display for IoModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            IoModel::Threads => "threads",
            IoModel::Poll => "poll",
        })
    }
}

impl std::str::FromStr for IoModel {
    type Err = String;

    fn from_str(s: &str) -> Result<IoModel, String> {
        match s {
            "threads" => Ok(IoModel::Threads),
            "poll" => Ok(IoModel::Poll),
            other => Err(format!("unknown io model '{other}' (threads|poll)")),
        }
    }
}

/// Tuning for a [`WireServer`].
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Which I/O backend serves connections.
    pub io_model: IoModel,
    /// Handler-pool size: the maximum number of concurrently served
    /// connections (further connections wait in the accept backlog).
    /// [`IoModel::Threads`] only.
    pub handlers: usize,
    /// Admission cap on tracked connections ([`IoModel::Poll`] only):
    /// a connection accepted past the cap is sent one typed
    /// over-capacity frame ([`Op::Shutdown`] op byte, `STATUS_TOO_LARGE`)
    /// and closed — counted by the `pol_wire_conns_shed` series — while
    /// admitted connections keep answering. Clamped to ≥ 1.
    pub max_conns: usize,
    /// Frames answered per connection per readiness-loop wakeup
    /// ([`IoModel::Poll`] only) — the fairness quantum: a peer
    /// streaming max-rate pipelined frames yields the loop to every
    /// other ready connection after this many answers. Clamped to ≥ 1.
    pub frame_budget: u32,
    /// How often a blocked handler wakes to check for shutdown.
    pub poll: Duration,
    /// Honour the [`Op::Shutdown`] admin frame. Disable for servers
    /// that must only stop from the owning process.
    pub allow_remote_shutdown: bool,
    /// Close a connection that goes this long without completing a
    /// frame, and bound every response write by the same duration —
    /// the slow-loris guard in both directions: with a bounded handler
    /// pool, a socket that neither sends frames nor drains responses
    /// would otherwise pin a handler forever and starve every later
    /// client (and wedge shutdown on the join). `None` disables both
    /// deadlines (trusted networks).
    pub idle_timeout: Option<Duration>,
    /// Per-connection stats flush cadence, in answered predict frames:
    /// handlers record into private buffers (no lock, no allocation on
    /// the hot path) and merge into the shared map this often — plus at
    /// connection close (including idle-timeout disconnects) and before
    /// answering a `Stats`/`MetricsDump` op on their own connection, so
    /// a remote stats read lags a *live* connection by at most this
    /// many frames. Clamped to ≥ 1.
    pub stats_flush_frames: u32,
    /// Attach the process-wide telemetry registry: its series are
    /// folded into every `MetricsDump` response next to the wire's own
    /// counters (see [`crate::obs`] for the series table).
    pub obs: Option<Arc<Obs>>,
    /// Cadence of the in-server metrics-history sampler: every period
    /// a sampler thread snapshots the whole rendered registry into a
    /// bounded [`SeriesRing`], served back by the
    /// [`Op::MetricsHistory`] admin op (rates/trends become a
    /// server-side fact). `None` disables sampling (the history op
    /// then answers an empty ring).
    pub history_every: Option<Duration>,
    /// Snapshots the history ring retains (oldest overwritten first).
    /// Clamped to ≥ 1.
    pub history_len: usize,
    /// Write a `.poltrace` flight record (trace-ring tail + last-K
    /// history snapshots + [`WireConfig::digest`]) here when the
    /// server shuts down — graceful or drop-on-error alike. `None`
    /// disables the flight recorder.
    pub flight_path: Option<PathBuf>,
}

/// Default for [`WireConfig::stats_flush_frames`].
pub const DEFAULT_STATS_FLUSH_FRAMES: u32 = 64;

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            io_model: IoModel::Threads,
            handlers: 4,
            max_conns: DEFAULT_MAX_CONNS,
            frame_budget: DEFAULT_FRAME_BUDGET,
            poll: Duration::from_millis(25),
            allow_remote_shutdown: true,
            idle_timeout: Some(Duration::from_secs(300)),
            stats_flush_frames: DEFAULT_STATS_FLUSH_FRAMES,
            obs: None,
            history_every: Some(Duration::from_secs(1)),
            history_len: DEFAULT_SERIES_CAPACITY,
            flight_path: None,
        }
    }
}

impl WireConfig {
    /// FNV-1a digest over the canonical text of this config — stamped
    /// into flight records so a post-mortem knows what the server
    /// *was* without trusting ambient state.
    pub fn digest(&self) -> u64 {
        let text = format!(
            "io_model={} handlers={} max_conns={} frame_budget={} \
             poll_ms={} allow_remote_shutdown={} idle_timeout_ms={} \
             stats_flush_frames={} history_every_ms={} history_len={}",
            self.io_model,
            self.handlers,
            self.max_conns,
            self.frame_budget,
            self.poll.as_millis(),
            self.allow_remote_shutdown,
            self.idle_timeout.map_or(0, |t| t.as_millis()),
            self.stats_flush_frames,
            self.history_every.map_or(0, |t| t.as_millis()),
            self.history_len,
        );
        crate::hashing::fnv1a64(text.as_bytes())
    }
}

/// State shared by every handler (threads backend) or owned by the
/// readiness loop (poll backend) plus the public [`WireServer`]
/// handle. Crate-visible so [`crate::wire::poll`] drives the same
/// counters and stats map.
pub(crate) struct Shared {
    pub(crate) registry: Arc<ModelRegistry>,
    pub(crate) stop: AtomicBool,
    pub(crate) allow_remote_shutdown: bool,
    pub(crate) local_addr: SocketAddr,
    pub(crate) started: Instant,
    pub(crate) bytes_in: AtomicU64,
    pub(crate) bytes_out: AtomicU64,
    pub(crate) frames_in: AtomicU64,
    pub(crate) frames_out: AtomicU64,
    pub(crate) decode_errors: AtomicU64,
    pub(crate) connections: AtomicU64,
    pub(crate) active: AtomicU64,
    /// Connections refused by the poll backend's admission cap.
    pub(crate) shed: AtomicU64,
    /// Readiness-loop wakeups (sweeps); stays 0 on the threads backend.
    pub(crate) wakeups: AtomicU64,
    /// Frames answered per wakeup — the fairness-budget histogram.
    pub(crate) wakeup_frames: Mutex<HistogramSnapshot>,
    pub(crate) per_model: Mutex<std::collections::BTreeMap<String, ModelStats>>,
    pub(crate) stats_flush_frames: u32,
    pub(crate) obs: Option<Arc<Obs>>,
    /// The metrics-history ring the sampler fills and the
    /// [`Op::MetricsHistory`] op serves (empty when sampling is off).
    pub(crate) history: Arc<SeriesRing>,
    /// [`WireConfig::digest`], stamped into flight records.
    pub(crate) config_digest: u64,
    /// Where the shutdown flight record goes (`None` = disabled).
    pub(crate) flight_path: Option<PathBuf>,
}

impl Shared {
    pub(crate) fn trigger_stop(&self) {
        self.stop.store(true, Ordering::Release);
        // wake the acceptor if it is blocked in accept(): one throwaway
        // connection to ourselves, immediately dropped on the far
        // side. An unspecified bind address (0.0.0.0 / ::) is not
        // connectable on every platform — aim at the same-family
        // loopback instead.
        let mut addr = self.local_addr;
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr {
                SocketAddr::V4(_) => {
                    std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                }
                SocketAddr::V6(_) => {
                    std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                }
            });
        }
        let _ = TcpStream::connect(addr);
    }

    fn stats(&self) -> StatsReport {
        let models = {
            // merged monotonic counters; valid after any partial merge
            let per_model = self.per_model.lock().recover_poisoned();
            per_model
                .iter()
                .map(|(name, m)| ModelStatsReport {
                    name: name.clone(),
                    requests: m.requests,
                    predictions: m.predictions,
                    p50_ns: m.latency.quantile_ns(0.5),
                    p99_ns: m.latency.quantile_ns(0.99),
                    max_ns: m.latency.max_ns(),
                    max_staleness: m.max_staleness,
                })
                .collect()
        };
        StatsReport {
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            active_connections: self.active.load(Ordering::Relaxed),
            uptime_us: self.started.elapsed().as_micros() as u64,
            registry_version: self.registry.version(),
            registry_models: self.registry.len() as u64,
            models,
        }
    }
}

/// The threads the selected backend runs on — joined on shutdown/drop.
enum Backend {
    Threads {
        acceptor: Option<std::thread::JoinHandle<()>>,
        handlers: Vec<std::thread::JoinHandle<()>>,
    },
    Poll {
        looper: Option<std::thread::JoinHandle<()>>,
    },
}

impl Backend {
    fn join(&mut self) {
        match self {
            Backend::Threads { acceptor, handlers } => {
                if let Some(a) = acceptor.take() {
                    let _ = a.join();
                }
                for h in handlers.drain(..) {
                    let _ = h.join();
                }
            }
            Backend::Poll { looper } => {
                if let Some(l) = looper.take() {
                    let _ = l.join();
                }
            }
        }
    }
}

/// Handle to a running TCP serving front-end (see the module docs).
/// The public surface is identical for both backends.
pub struct WireServer {
    shared: Arc<Shared>,
    backend: Backend,
    sampler: Option<std::thread::JoinHandle<()>>,
    finalized: bool,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `registry` — models may be inserted, replaced, or
    /// removed while serving, and snapshot publishes through the cells
    /// are picked up per request, exactly like the in-process server.
    pub fn bind(
        addr: impl ToSocketAddrs,
        registry: Arc<ModelRegistry>,
        cfg: WireConfig,
    ) -> io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            stop: AtomicBool::new(false),
            allow_remote_shutdown: cfg.allow_remote_shutdown,
            local_addr,
            started: Instant::now(),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            active: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            wakeups: AtomicU64::new(0),
            wakeup_frames: Mutex::new(HistogramSnapshot::default()),
            per_model: Mutex::new(std::collections::BTreeMap::new()),
            stats_flush_frames: cfg.stats_flush_frames.max(1),
            obs: cfg.obs.clone(),
            history: Arc::new(SeriesRing::new(cfg.history_len.max(1))),
            config_digest: cfg.digest(),
            flight_path: cfg.flight_path.clone(),
        });
        // the history sampler: parse our own exposition each cadence
        // and push the raw totals into the bounded ring — rates are
        // derived at read time, never stored
        let mut sampler = None;
        if let Some(period) = cfg.history_every {
            let period = period.max(Duration::from_millis(1));
            let s = Arc::clone(&shared);
            sampler = Some(
                std::thread::Builder::new()
                    .name("wire-sampler".into())
                    .spawn(move || {
                        let step =
                            Duration::from_millis(25).min(period);
                        let mut next = Instant::now() + period;
                        while !s.stop.load(Ordering::Acquire) {
                            if Instant::now() >= next {
                                next = Instant::now() + period;
                                sample_history(&s);
                            }
                            std::thread::sleep(step);
                        }
                    })?,
            );
        }
        if cfg.io_model == IoModel::Poll {
            let params = crate::wire::poll::PollParams {
                poll: cfg.poll,
                idle_timeout: cfg.idle_timeout,
                max_conns: cfg.max_conns.max(1),
                frame_budget: cfg.frame_budget.max(1),
            };
            let loop_shared = Arc::clone(&shared);
            let looper = std::thread::Builder::new()
                .name("wire-poll".into())
                .spawn(move || {
                    crate::wire::poll::PollServer::new(
                        loop_shared,
                        listener,
                        params,
                    )
                    .run()
                })?;
            return Ok(WireServer {
                shared,
                backend: Backend::Poll { looper: Some(looper) },
                sampler,
                finalized: false,
            });
        }
        let handlers_n = cfg.handlers.max(1);
        // rendezvous-ish queue: the acceptor blocks once every handler
        // is busy, so the kernel backlog is the only connection queue
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(handlers_n);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::with_capacity(handlers_n);
        for hid in 0..handlers_n {
            let shared = Arc::clone(&shared);
            let conn_rx = Arc::clone(&conn_rx);
            let poll = cfg.poll;
            let idle = cfg.idle_timeout;
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("wire-{hid}"))
                    .spawn(move || loop {
                        let stream = {
                            // the shared receiver has no partial state;
                            // recover from a peer handler's panic
                            let guard = conn_rx.lock().recover_poisoned();
                            guard.recv()
                        };
                        match stream {
                            Ok(s) => {
                                shared.active.fetch_add(1, Ordering::Relaxed);
                                handle_conn(&shared, s, poll, idle);
                                shared.active.fetch_sub(1, Ordering::Relaxed);
                            }
                            Err(_) => break, // acceptor gone: shutting down
                        }
                    })?,
            );
        }
        let acceptor_shared = Arc::clone(&shared);
        let accept_backoff = cfg.poll;
        let acceptor = std::thread::Builder::new()
            .name("wire-accept".into())
            .spawn(move || {
                loop {
                    if acceptor_shared.stop.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if acceptor_shared.stop.load(Ordering::Acquire) {
                                break; // the wake-up connection
                            }
                            acceptor_shared
                                .connections
                                .fetch_add(1, Ordering::Relaxed);
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            // transient accept failures (EMFILE under
                            // a connection flood) must not hot-loop
                            // the acceptor at 100% CPU
                            std::thread::sleep(accept_backoff);
                        }
                    }
                }
                // conn_tx drops here; idle handlers exit on recv error
            })?;
        Ok(WireServer {
            shared,
            backend: Backend::Threads { acceptor: Some(acceptor), handlers },
            sampler,
            finalized: false,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Live wire-level + per-model stats (also served remotely through
    /// the [`Op::Stats`] admin op).
    pub fn stats(&self) -> StatsReport {
        self.shared.stats()
    }

    /// Whether a drain has been requested (locally or by a
    /// [`Op::Shutdown`] admin frame).
    pub fn is_draining(&self) -> bool {
        self.shared.stop.load(Ordering::Acquire)
    }

    /// Block until a drain is requested — the serve-forever loop of
    /// `pol serve --listen` (a remote [`Op::Shutdown`] frame, when
    /// permitted, is the off switch).
    pub fn wait(&self) {
        while !self.is_draining() {
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// The metrics-history ring (what [`Op::MetricsHistory`] serves).
    pub fn history(&self) -> Arc<SeriesRing> {
        Arc::clone(&self.shared.history)
    }

    /// Stop accepting, drain in-flight connections (each answers at
    /// most [`DRAIN_FRAMES`] more frames), join every thread, write
    /// the flight record (when configured), and report final stats.
    pub fn shutdown(mut self) -> StatsReport {
        self.finalize();
        self.shared.stats()
    }

    /// The one stop path both [`WireServer::shutdown`] and drop run:
    /// stop, join every thread, then write the flight record exactly
    /// once — an errored server that merely drops still leaves a
    /// post-mortem behind.
    fn finalize(&mut self) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        self.shared.trigger_stop();
        self.backend.join();
        if let Some(s) = self.sampler.take() {
            let _ = s.join();
        }
        write_flight_record(&self.shared);
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        // dropping without shutdown() still stops the threads and
        // still writes the flight record
        self.finalize();
    }
}

/// One sampler tick: render the same exposition `MetricsDump` serves,
/// parse it back (the render→parse inverse is test-pinned), and push
/// the raw totals into the ring stamped with server uptime.
fn sample_history(shared: &Shared) {
    if let Some(series) =
        crate::obs::parse_exposition(&render_metrics(shared))
    {
        let uptime_ms = shared.started.elapsed().as_millis() as u64;
        shared.history.push(uptime_ms, series);
    }
}

/// Serialize the flight record at shutdown: trace-ring tail, the
/// history ring's newest snapshots, and the config digest, written
/// atomically to [`Shared::flight_path`]. Failures are swallowed — a
/// post-mortem writer must never turn shutdown into a crash.
fn write_flight_record(shared: &Shared) {
    let Some(path) = &shared.flight_path else { return };
    let events = match &shared.obs {
        Some(o) => o
            .trace
            .tail(crate::obs::trace::MAX_TRAILER_EVENTS as usize),
        None => Vec::new(),
    };
    let rec = FlightRecord {
        config_digest: shared.config_digest,
        events,
        snapshots: shared.history.tail(MAX_HISTORY_SNAPSHOTS as usize),
    };
    let _ = crate::obs::write_flight(path, &rec);
}

/// Send one frame (sealing the checksum), flush it, and account it.
/// The poll backend's `w` is a connection's pending-output buffer
/// (`Vec<u8>` — `flush` is a no-op there); the threads backend's is a
/// `BufWriter` over the socket.
pub(crate) fn send_frame(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
) -> io::Result<()> {
    let n = out.finish_to(w)?;
    w.flush()?;
    shared.frames_out.fetch_add(1, Ordering::Relaxed);
    shared.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
    Ok(())
}

/// Send a typed error frame: same op and request id, error status,
/// UTF-8 message payload.
pub(crate) fn send_error(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
    op: u8,
    status: u8,
    req_id: u64,
    msg: &str,
) -> io::Result<()> {
    out.start(op, status, req_id);
    out.payload().extend_from_slice(msg.as_bytes());
    send_frame(shared, out, w)
}

/// Merge a connection's private per-model stats into the shared map
/// and zero the private buffers (keys are kept, so steady state
/// re-allocates nothing). Both backends call this at every flush
/// cadence boundary AND whenever a connection closes — including the
/// poll backend's idle-timeout and drain closes — so no answered
/// frame is ever lost to the stats plane.
pub(crate) fn flush_stats(
    shared: &Shared,
    local: &mut std::collections::HashMap<String, ModelStats>,
) {
    if local.values().all(|m| m.requests == 0) {
        return;
    }
    // merged monotonic counters; valid after any partial merge
    let mut per_model = shared.per_model.lock().recover_poisoned();
    for (name, ms) in local.iter_mut() {
        if ms.requests == 0 {
            continue;
        }
        match per_model.get_mut(name) {
            Some(entry) => entry.merge(ms),
            None => {
                per_model.insert(name.clone(), ms.clone());
            }
        }
        *ms = ModelStats::new();
    }
}

/// Render the full metrics exposition for a `MetricsDump` response:
/// the wire layer's own counters, the per-model serving series from
/// the shared stats map, registry state, and — when the process-wide
/// [`Obs`] handle is attached — every series the training/streaming
/// layers recorded into it. One text, one format, one source of truth
/// (the same bytes `pol metrics`/`pol top --once` print).
fn render_metrics(shared: &Shared) -> String {
    let mut exp = Exposition::new();
    exp.point(
        names::WIRE_BYTES_IN_TOTAL,
        &[],
        shared.bytes_in.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_BYTES_OUT_TOTAL,
        &[],
        shared.bytes_out.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_FRAMES_IN_TOTAL,
        &[],
        shared.frames_in.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_FRAMES_OUT_TOTAL,
        &[],
        shared.frames_out.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_DECODE_ERRORS_TOTAL,
        &[],
        shared.decode_errors.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_CONNECTIONS_TOTAL,
        &[],
        shared.connections.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_ACTIVE_CONNECTIONS,
        &[],
        shared.active.load(Ordering::Relaxed),
    );
    // event-loop series (the threads backend reports zeros for the
    // loop-only counters; conns_active is live on both)
    exp.point(
        names::WIRE_CONNS_ACTIVE,
        &[],
        shared.active.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_CONNS_SHED,
        &[],
        shared.shed.load(Ordering::Relaxed),
    );
    exp.point(
        names::WIRE_WAKEUPS,
        &[],
        shared.wakeups.load(Ordering::Relaxed),
    );
    {
        // per-wakeup frames-answered histogram; valid after any merge
        let wf = shared.wakeup_frames.lock().recover_poisoned();
        exp.histogram(names::WIRE_WAKEUP_FRAMES, &[], &wf);
    }
    exp.point(
        names::SERVE_REGISTRY_VERSION,
        &[],
        shared.registry.version(),
    );
    exp.point(names::SERVE_MODELS, &[], shared.registry.len() as u64);
    {
        // merged monotonic counters; valid after any partial merge
        let per_model = shared.per_model.lock().recover_poisoned();
        for (name, m) in per_model.iter() {
            let labels = [("model", name.as_str())];
            exp.point(names::SERVE_REQUESTS_TOTAL, &labels, m.requests);
            exp.point(
                names::SERVE_PREDICTIONS_TOTAL,
                &labels,
                m.predictions,
            );
            exp.point(names::SERVE_STALENESS_MAX, &labels, m.max_staleness);
            exp.histogram(
                names::SERVE_LATENCY_NS,
                &labels,
                &HistogramSnapshot::from_latency(&m.latency),
            );
        }
    }
    if let Some(o) = &shared.obs {
        // ring-loss visibility rides the wire render, not Obs::new()
        // registration — the golden exposition bytes stay pinned
        exp.point(names::TRACE_DROPPED, &[], o.trace.dropped());
        o.metrics.render_into(&mut exp);
    }
    exp.render()
}

/// Per-handler scoring state: the registry cache and the recycled
/// decode/predict buffers. One per handler thread on the threads
/// backend; the poll backend's single loop owns exactly one and shares
/// it across every multiplexed connection (safe — the loop is
/// single-threaded — and it keeps the cache hot across peers).
pub(crate) struct HandlerCtx {
    cache: ModelCache,
    scratch: BatchScratch,
    preds: Vec<f64>,
    /// Phase-attributed span recorder — live when [`Shared::obs`] is
    /// attached, a no-op (zero extra clock reads) otherwise. Living
    /// here means both backends instrument through the one dispatch
    /// point and cannot drift.
    spans: PhaseSpans,
}

impl HandlerCtx {
    /// Fresh scoring state over `shared`'s registry, recording phase
    /// spans iff `shared` carries an [`Obs`] handle.
    pub(crate) fn new(shared: &Shared) -> HandlerCtx {
        HandlerCtx {
            cache: ModelCache::new(&shared.registry),
            scratch: BatchScratch::default(),
            preds: Vec::new(),
            spans: PhaseSpans::from_obs(shared.obs.as_ref()),
        }
    }
}

/// The `op` label value for a phase span.
fn op_label(op: Op) -> &'static str {
    match op {
        Op::Predict => "predict",
        Op::PredictBatch => "predict_batch",
        Op::Stats => "stats",
        Op::ListModels => "list_models",
        Op::Ping => "ping",
        Op::Shutdown => "shutdown",
        Op::MetricsDump => "metrics_dump",
        Op::MetricsHistory => "metrics_history",
    }
}

/// [`send_frame`] with the `write_flush` phase recorded around it
/// (skipping the clock reads entirely when spans are disabled).
fn send_frame_timed(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
    spans: &mut PhaseSpans,
    op: &'static str,
) -> io::Result<()> {
    if !spans.enabled() {
        return send_frame(shared, out, w);
    }
    let t = Instant::now();
    let sent = send_frame(shared, out, w);
    spans.record(op, Phase::WriteFlush, t.elapsed());
    sent
}

/// Answer one decoded frame — the single op dispatch both backends
/// run, so every response byte (prediction bits included) is identical
/// between them by construction. The caller has already accounted
/// `frames_in`/`bytes_in`; this accounts everything outgoing through
/// [`send_frame`]. `local_stats`/`unflushed` are the calling
/// connection's private stats buffer and its flush-cadence counter.
pub(crate) fn answer_frame(
    shared: &Shared,
    frame: &crate::wire::frame::Frame<'_>,
    ctx: &mut HandlerCtx,
    out: &mut FrameWriter,
    w: &mut impl Write,
    local_stats: &mut std::collections::HashMap<String, ModelStats>,
    unflushed: &mut u32,
) -> io::Result<()> {
    let op = frame.op;
    let req_id = frame.req_id;
    let enqueued = Instant::now();
    match Op::from_u8(op) {
        None => send_error(
            shared,
            out,
            w,
            op,
            STATUS_UNKNOWN_OP,
            req_id,
            &format!("unknown op {op}"),
        ),
        Some(kind @ (Op::Predict | Op::PredictBatch)) => {
            let lbl = op_label(kind);
            match decode_predict_request(kind, frame.payload, &mut ctx.scratch)
            {
                Ok(name) => {
                    // span marks are taken only when recording is live,
                    // so un-instrumented serving pays no extra clock
                    // reads; recording never touches the response bytes
                    let timed = ctx.spans.enabled();
                    let mut mark = enqueued;
                    if timed {
                        let now = Instant::now();
                        ctx.spans.record(
                            lbl,
                            Phase::ReadDecode,
                            now.duration_since(mark),
                        );
                        mark = now;
                    }
                    match ctx.cache.resolve(&shared.registry, name) {
                        Some((snap_reader, pscratch)) => {
                            let snap = Arc::clone(snap_reader.current());
                            ctx.preds.clear();
                            for x in ctx.scratch.batch() {
                                ctx.preds.push(snap.predict_with(x, pscratch));
                            }
                            let staleness =
                                snap_reader.cell().staleness_of(&snap);
                            if timed {
                                let now = Instant::now();
                                ctx.spans.record(
                                    lbl,
                                    Phase::Predict,
                                    now.duration_since(mark),
                                );
                                mark = now;
                            }
                            out.start(op, STATUS_OK, req_id);
                            put_predict_response(
                                out.payload(),
                                &ctx.preds,
                                snap.version,
                                staleness,
                            );
                            if timed {
                                ctx.spans.record(
                                    lbl,
                                    Phase::Encode,
                                    mark.elapsed(),
                                );
                            }
                            let sent = send_frame_timed(
                                shared,
                                out,
                                w,
                                &mut ctx.spans,
                                lbl,
                            );
                            if sent.is_ok() {
                                // private buffer: no lock, no
                                // allocation once the name is known
                                match local_stats.get_mut(name) {
                                    Some(ms) => ms.record(
                                        ctx.preds.len() as u64,
                                        enqueued.elapsed(),
                                        staleness,
                                    ),
                                    None => {
                                        let mut ms = ModelStats::new();
                                        ms.record(
                                            ctx.preds.len() as u64,
                                            enqueued.elapsed(),
                                            staleness,
                                        );
                                        local_stats.insert(
                                            name.to_string(),
                                            ms,
                                        );
                                    }
                                }
                                *unflushed += 1;
                                if *unflushed >= shared.stats_flush_frames {
                                    flush_stats(shared, local_stats);
                                    *unflushed = 0;
                                }
                            }
                            sent
                        }
                        None => send_error(
                            shared,
                            out,
                            w,
                            op,
                            STATUS_UNKNOWN_MODEL,
                            req_id,
                            &format!("unknown model '{name}'"),
                        ),
                    }
                }
                Err(e) => {
                    shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                    let status = match e {
                        FrameError::OverCap(_) => STATUS_TOO_LARGE,
                        _ => STATUS_BAD_FRAME,
                    };
                    send_error(shared, out, w, op, status, req_id, &e.to_string())
                }
            }
        }
        Some(Op::Stats) => {
            // publish this connection's own numbers first, so a client
            // polling stats on the connection it queries through
            // always sees itself
            flush_stats(shared, local_stats);
            *unflushed = 0;
            let t = ctx.spans.enabled().then(Instant::now);
            out.start(op, STATUS_OK, req_id);
            put_stats(out.payload(), &shared.stats());
            if let Some(t) = t {
                ctx.spans.record("stats", Phase::Encode, t.elapsed());
            }
            send_frame_timed(shared, out, w, &mut ctx.spans, "stats")
        }
        Some(Op::MetricsDump) => {
            if !frame.payload.is_empty() {
                shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                send_error(
                    shared,
                    out,
                    w,
                    op,
                    STATUS_BAD_FRAME,
                    req_id,
                    "metrics dump request carries a payload",
                )
            } else {
                // same self-visibility rule as Stats: fold this
                // connection's numbers in first
                flush_stats(shared, local_stats);
                *unflushed = 0;
                let t = ctx.spans.enabled().then(Instant::now);
                out.start(op, STATUS_OK, req_id);
                out.payload()
                    .extend_from_slice(render_metrics(shared).as_bytes());
                if let Some(t) = t {
                    ctx.spans.record(
                        "metrics_dump",
                        Phase::Encode,
                        t.elapsed(),
                    );
                }
                send_frame_timed(
                    shared,
                    out,
                    w,
                    &mut ctx.spans,
                    "metrics_dump",
                )
            }
        }
        Some(Op::MetricsHistory) => {
            if !frame.payload.is_empty() {
                shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                send_error(
                    shared,
                    out,
                    w,
                    op,
                    STATUS_BAD_FRAME,
                    req_id,
                    "metrics history request carries a payload",
                )
            } else {
                let t = ctx.spans.enabled().then(Instant::now);
                out.start(op, STATUS_OK, req_id);
                put_history(
                    out.payload(),
                    &shared.history.tail(MAX_HISTORY_SNAPSHOTS as usize),
                );
                if let Some(t) = t {
                    ctx.spans.record(
                        "metrics_history",
                        Phase::Encode,
                        t.elapsed(),
                    );
                }
                send_frame_timed(
                    shared,
                    out,
                    w,
                    &mut ctx.spans,
                    "metrics_history",
                )
            }
        }
        Some(Op::ListModels) => {
            let t = ctx.spans.enabled().then(Instant::now);
            let mut models = Vec::new();
            for name in shared.registry.names() {
                let Some(cell) = shared.registry.get(&name) else {
                    continue; // removed between names() and get
                };
                let snap = cell.load();
                models.push(ModelEntry {
                    name,
                    dim: snap.dim() as u64,
                    params: snap.num_params() as u64,
                    snapshot_version: snap.version,
                    trained_instances: snap.trained_instances,
                });
            }
            out.start(op, STATUS_OK, req_id);
            put_models(out.payload(), &models);
            if let Some(t) = t {
                ctx.spans.record("list_models", Phase::Encode, t.elapsed());
            }
            send_frame_timed(shared, out, w, &mut ctx.spans, "list_models")
        }
        Some(Op::Ping) => {
            if frame.payload.len() > MAX_PING {
                send_error(
                    shared,
                    out,
                    w,
                    op,
                    STATUS_TOO_LARGE,
                    req_id,
                    &format!(
                        "ping payload {} bytes (cap {MAX_PING})",
                        frame.payload.len()
                    ),
                )
            } else {
                let t = ctx.spans.enabled().then(Instant::now);
                out.start(op, STATUS_OK, req_id);
                out.payload().extend_from_slice(frame.payload);
                if let Some(t) = t {
                    ctx.spans.record("ping", Phase::Encode, t.elapsed());
                }
                send_frame_timed(shared, out, w, &mut ctx.spans, "ping")
            }
        }
        Some(Op::Shutdown) => {
            if shared.allow_remote_shutdown {
                let sent =
                    send_error(shared, out, w, op, STATUS_OK, req_id, "draining");
                shared.trigger_stop();
                sent
            } else {
                send_error(
                    shared,
                    out,
                    w,
                    op,
                    STATUS_FORBIDDEN,
                    req_id,
                    "remote shutdown disabled on this server",
                )
            }
        }
    }
}

/// Send the typed end-of-stream frame a draining connection owes its
/// pipelined peers (and that a shed connection gets instead of silent
/// queue collapse): [`Op::Shutdown`] op byte, `status`, request id 0.
pub(crate) fn send_goodbye(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
    status: u8,
    msg: &str,
) -> io::Result<()> {
    send_error(
        shared,
        out,
        w,
        // pol-lint: allow(L006, "Op discriminants are u8 by definition")
        Op::Shutdown as u8,
        status,
        0,
        msg,
    )
}

/// Serve one connection to completion on a handler thread (threads
/// backend; see the module docs for the close-vs-error-frame policy).
fn handle_conn(
    shared: &Shared,
    stream: TcpStream,
    poll: Duration,
    idle: Option<Duration>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(poll));
    // a peer that sends requests but never drains responses must not
    // wedge the handler in write_all: bound writes by the same
    // deadline that bounds idle reads (a timed-out write errors the
    // send and closes the connection)
    let _ = stream.set_write_timeout(idle);
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::with_capacity(1 << 16, stream);
    let mut writer = BufWriter::with_capacity(1 << 16, write_half);
    let mut buf = FrameBuf::new();
    let mut out = FrameWriter::new();
    let mut ctx = HandlerCtx::new(shared);
    let mut local_stats: std::collections::HashMap<String, ModelStats> =
        std::collections::HashMap::new();
    let mut unflushed = 0u32;
    let mut drained = 0u32;
    loop {
        let draining = shared.stop.load(Ordering::Acquire);
        if draining {
            drained += 1;
            if drained > DRAIN_FRAMES {
                break;
            }
        }
        let idle_deadline = idle.map(|t| Instant::now() + t);
        match read_frame(
            &mut reader,
            &mut buf,
            Some(&shared.stop),
            idle_deadline,
        ) {
            Ok(None) => break, // clean close, or idle while draining
            Ok(Some(frame)) => {
                shared.frames_in.fetch_add(1, Ordering::Relaxed);
                shared
                    .bytes_in
                    .fetch_add(frame.wire_bytes as u64, Ordering::Relaxed);
                let outcome = answer_frame(
                    shared,
                    &frame,
                    &mut ctx,
                    &mut out,
                    &mut writer,
                    &mut local_stats,
                    &mut unflushed,
                );
                if outcome.is_err() {
                    break; // peer went away mid-write
                }
            }
            Err(FrameError::Io(_)) => break, // transport failure
            Err(_) => {
                // framing-level corruption: the stream cannot be
                // resynchronized, so count it and close cleanly —
                // never panic, never allocate toward a hostile length
                shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }
    flush_stats(shared, &mut local_stats);
    // a draining handler tells pipelined peers why the stream ends
    if shared.stop.load(Ordering::Acquire) {
        let _ = send_goodbye(
            shared,
            &mut out,
            &mut writer,
            STATUS_SHUTTING_DOWN,
            "server draining",
        );
    }
}
