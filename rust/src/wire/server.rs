//! [`WireServer`] — the TCP front-end over the serving registry.
//!
//! One `std::net::TcpListener` acceptor thread feeds accepted
//! connections to a **bounded** pool of handler threads (the pool size
//! is the concurrency cap; further connections queue in the kernel
//! accept backlog — a connection flood cannot spawn unbounded
//! threads). Each handler owns exactly the per-connection state the
//! in-process serving workers own per thread: a
//! [`ModelCache`] of `(reader, scratch)` pairs, a recycled
//! [`FrameBuf`]/[`FrameWriter`], and recycled decode/predict buffers —
//! the steady-state request path allocates nothing, and scoring drives
//! the *same* [`crate::serve::ModelRegistry`]/snapshot read path as
//! [`crate::serve::PredictionServer`], so wire answers are
//! bit-identical to in-process answers by construction.
//!
//! Requests pipeline: a client may send many frames without waiting;
//! the handler answers them in arrival order and every response
//! carries the request id it answers. Malformed *payloads* get typed
//! error frames; framing-level corruption (bad length, magic, version,
//! checksum, truncation) means the byte stream can no longer be
//! trusted, so the connection closes cleanly instead — either way a
//! hostile peer gets bounded allocation and no panic.
//!
//! Shutdown drains gracefully: [`WireServer::shutdown`] (or a
//! [`Op::Shutdown`] admin frame, when permitted) stops the acceptor,
//! lets every handler answer the frames already buffered on its
//! connection (bounded by [`DRAIN_FRAMES`]), then closes. Wire-level
//! totals (bytes/frames/decode errors) and per-model latency
//! histograms are readable live through [`WireServer::stats`] or
//! remotely via the [`Op::Stats`] admin op.

// Every Relaxed here is monotonic telemetry (byte/frame/connection
// counters, the active-handler gauge); cross-thread hand-off of real
// data goes through channels and mutexes, never through these atomics.
// pol-lint: allow-file(L002, "wire counters are monotonic telemetry")

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::LockExt;
use crate::obs::{Exposition, HistogramSnapshot, Obs};
use crate::serve::registry::{ModelCache, ModelRegistry};
use crate::serve::server::ModelStats;
use crate::wire::frame::{
    decode_predict_request, put_models, put_predict_response, put_stats,
    read_frame, BatchScratch, FrameBuf, FrameError, FrameWriter, ModelEntry,
    ModelStatsReport, Op, StatsReport, MAX_PING, STATUS_BAD_FRAME,
    STATUS_FORBIDDEN, STATUS_OK, STATUS_SHUTTING_DOWN, STATUS_TOO_LARGE,
    STATUS_UNKNOWN_MODEL, STATUS_UNKNOWN_OP,
};

/// Frames a draining handler still answers before closing its
/// connection — bounded so a peer that keeps streaming cannot hold the
/// drain open forever.
pub const DRAIN_FRAMES: u32 = 256;

/// Tuning for a [`WireServer`].
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Handler-pool size: the maximum number of concurrently served
    /// connections (further connections wait in the accept backlog).
    pub handlers: usize,
    /// How often a blocked handler wakes to check for shutdown.
    pub poll: Duration,
    /// Honour the [`Op::Shutdown`] admin frame. Disable for servers
    /// that must only stop from the owning process.
    pub allow_remote_shutdown: bool,
    /// Close a connection that goes this long without completing a
    /// frame, and bound every response write by the same duration —
    /// the slow-loris guard in both directions: with a bounded handler
    /// pool, a socket that neither sends frames nor drains responses
    /// would otherwise pin a handler forever and starve every later
    /// client (and wedge shutdown on the join). `None` disables both
    /// deadlines (trusted networks).
    pub idle_timeout: Option<Duration>,
    /// Per-connection stats flush cadence, in answered predict frames:
    /// handlers record into private buffers (no lock, no allocation on
    /// the hot path) and merge into the shared map this often — plus at
    /// connection close (including idle-timeout disconnects) and before
    /// answering a `Stats`/`MetricsDump` op on their own connection, so
    /// a remote stats read lags a *live* connection by at most this
    /// many frames. Clamped to ≥ 1.
    pub stats_flush_frames: u32,
    /// Attach the process-wide telemetry registry: its series are
    /// folded into every `MetricsDump` response next to the wire's own
    /// counters (see [`crate::obs`] for the series table).
    pub obs: Option<Arc<Obs>>,
}

/// Default for [`WireConfig::stats_flush_frames`].
pub const DEFAULT_STATS_FLUSH_FRAMES: u32 = 64;

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            handlers: 4,
            poll: Duration::from_millis(25),
            allow_remote_shutdown: true,
            idle_timeout: Some(Duration::from_secs(300)),
            stats_flush_frames: DEFAULT_STATS_FLUSH_FRAMES,
            obs: None,
        }
    }
}

struct Shared {
    registry: Arc<ModelRegistry>,
    stop: AtomicBool,
    allow_remote_shutdown: bool,
    local_addr: SocketAddr,
    started: Instant,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    decode_errors: AtomicU64,
    connections: AtomicU64,
    active: AtomicU64,
    per_model: Mutex<std::collections::BTreeMap<String, ModelStats>>,
    stats_flush_frames: u32,
    obs: Option<Arc<Obs>>,
}

impl Shared {
    fn trigger_stop(&self) {
        self.stop.store(true, Ordering::Release);
        // wake the acceptor if it is blocked in accept(): one throwaway
        // connection to ourselves, immediately dropped on the far
        // side. An unspecified bind address (0.0.0.0 / ::) is not
        // connectable on every platform — aim at the same-family
        // loopback instead.
        let mut addr = self.local_addr;
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr {
                SocketAddr::V4(_) => {
                    std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                }
                SocketAddr::V6(_) => {
                    std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                }
            });
        }
        let _ = TcpStream::connect(addr);
    }

    fn stats(&self) -> StatsReport {
        let models = {
            // merged monotonic counters; valid after any partial merge
            let per_model = self.per_model.lock().recover_poisoned();
            per_model
                .iter()
                .map(|(name, m)| ModelStatsReport {
                    name: name.clone(),
                    requests: m.requests,
                    predictions: m.predictions,
                    p50_ns: m.latency.quantile_ns(0.5),
                    p99_ns: m.latency.quantile_ns(0.99),
                    max_ns: m.latency.max_ns(),
                    max_staleness: m.max_staleness,
                })
                .collect()
        };
        StatsReport {
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            active_connections: self.active.load(Ordering::Relaxed),
            uptime_us: self.started.elapsed().as_micros() as u64,
            registry_version: self.registry.version(),
            registry_models: self.registry.len() as u64,
            models,
        }
    }
}

/// Handle to a running TCP serving front-end (see the module docs).
pub struct WireServer {
    shared: Arc<Shared>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    handlers: Vec<std::thread::JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `registry` — models may be inserted, replaced, or
    /// removed while serving, and snapshot publishes through the cells
    /// are picked up per request, exactly like the in-process server.
    pub fn bind(
        addr: impl ToSocketAddrs,
        registry: Arc<ModelRegistry>,
        cfg: WireConfig,
    ) -> io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            stop: AtomicBool::new(false),
            allow_remote_shutdown: cfg.allow_remote_shutdown,
            local_addr,
            started: Instant::now(),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            active: AtomicU64::new(0),
            per_model: Mutex::new(std::collections::BTreeMap::new()),
            stats_flush_frames: cfg.stats_flush_frames.max(1),
            obs: cfg.obs.clone(),
        });
        let handlers_n = cfg.handlers.max(1);
        // rendezvous-ish queue: the acceptor blocks once every handler
        // is busy, so the kernel backlog is the only connection queue
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(handlers_n);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::with_capacity(handlers_n);
        for hid in 0..handlers_n {
            let shared = Arc::clone(&shared);
            let conn_rx = Arc::clone(&conn_rx);
            let poll = cfg.poll;
            let idle = cfg.idle_timeout;
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("wire-{hid}"))
                    .spawn(move || loop {
                        let stream = {
                            // the shared receiver has no partial state;
                            // recover from a peer handler's panic
                            let guard = conn_rx.lock().recover_poisoned();
                            guard.recv()
                        };
                        match stream {
                            Ok(s) => {
                                shared.active.fetch_add(1, Ordering::Relaxed);
                                handle_conn(&shared, s, poll, idle);
                                shared.active.fetch_sub(1, Ordering::Relaxed);
                            }
                            Err(_) => break, // acceptor gone: shutting down
                        }
                    })?,
            );
        }
        let acceptor_shared = Arc::clone(&shared);
        let accept_backoff = cfg.poll;
        let acceptor = std::thread::Builder::new()
            .name("wire-accept".into())
            .spawn(move || {
                loop {
                    if acceptor_shared.stop.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if acceptor_shared.stop.load(Ordering::Acquire) {
                                break; // the wake-up connection
                            }
                            acceptor_shared
                                .connections
                                .fetch_add(1, Ordering::Relaxed);
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            // transient accept failures (EMFILE under
                            // a connection flood) must not hot-loop
                            // the acceptor at 100% CPU
                            std::thread::sleep(accept_backoff);
                        }
                    }
                }
                // conn_tx drops here; idle handlers exit on recv error
            })?;
        Ok(WireServer { shared, acceptor: Some(acceptor), handlers })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Live wire-level + per-model stats (also served remotely through
    /// the [`Op::Stats`] admin op).
    pub fn stats(&self) -> StatsReport {
        self.shared.stats()
    }

    /// Whether a drain has been requested (locally or by a
    /// [`Op::Shutdown`] admin frame).
    pub fn is_draining(&self) -> bool {
        self.shared.stop.load(Ordering::Acquire)
    }

    /// Block until a drain is requested — the serve-forever loop of
    /// `pol serve --listen` (a remote [`Op::Shutdown`] frame, when
    /// permitted, is the off switch).
    pub fn wait(&self) {
        while !self.is_draining() {
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Stop accepting, drain in-flight connections (each answers at
    /// most [`DRAIN_FRAMES`] more frames), join every thread, and
    /// report final stats.
    pub fn shutdown(mut self) -> StatsReport {
        self.shared.trigger_stop();
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
        self.shared.stats()
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        // dropping without shutdown() still stops the threads
        self.shared.trigger_stop();
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Send one frame (sealing the checksum), flush it, and account it.
fn send_frame(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
) -> io::Result<()> {
    let n = out.finish_to(w)?;
    w.flush()?;
    shared.frames_out.fetch_add(1, Ordering::Relaxed);
    shared.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
    Ok(())
}

/// Send a typed error frame: same op and request id, error status,
/// UTF-8 message payload.
fn send_error(
    shared: &Shared,
    out: &mut FrameWriter,
    w: &mut impl Write,
    op: u8,
    status: u8,
    req_id: u64,
    msg: &str,
) -> io::Result<()> {
    out.start(op, status, req_id);
    out.payload().extend_from_slice(msg.as_bytes());
    send_frame(shared, out, w)
}

/// Merge a connection's private per-model stats into the shared map
/// and zero the private buffers (keys are kept, so steady state
/// re-allocates nothing).
fn flush_stats(
    shared: &Shared,
    local: &mut std::collections::HashMap<String, ModelStats>,
) {
    if local.values().all(|m| m.requests == 0) {
        return;
    }
    // merged monotonic counters; valid after any partial merge
    let mut per_model = shared.per_model.lock().recover_poisoned();
    for (name, ms) in local.iter_mut() {
        if ms.requests == 0 {
            continue;
        }
        match per_model.get_mut(name) {
            Some(entry) => entry.merge(ms),
            None => {
                per_model.insert(name.clone(), ms.clone());
            }
        }
        *ms = ModelStats::new();
    }
}

/// Render the full metrics exposition for a `MetricsDump` response:
/// the wire layer's own counters, the per-model serving series from
/// the shared stats map, registry state, and — when the process-wide
/// [`Obs`] handle is attached — every series the training/streaming
/// layers recorded into it. One text, one format, one source of truth
/// (the same bytes `pol metrics`/`pol top --once` print).
fn render_metrics(shared: &Shared) -> String {
    let mut exp = Exposition::new();
    exp.point(
        "pol_wire_bytes_in_total",
        &[],
        shared.bytes_in.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_bytes_out_total",
        &[],
        shared.bytes_out.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_frames_in_total",
        &[],
        shared.frames_in.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_frames_out_total",
        &[],
        shared.frames_out.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_decode_errors_total",
        &[],
        shared.decode_errors.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_connections_total",
        &[],
        shared.connections.load(Ordering::Relaxed),
    );
    exp.point(
        "pol_wire_active_connections",
        &[],
        shared.active.load(Ordering::Relaxed),
    );
    exp.point("pol_serve_registry_version", &[], shared.registry.version());
    exp.point("pol_serve_models", &[], shared.registry.len() as u64);
    {
        // merged monotonic counters; valid after any partial merge
        let per_model = shared.per_model.lock().recover_poisoned();
        for (name, m) in per_model.iter() {
            let labels = [("model", name.as_str())];
            exp.point("pol_serve_requests_total", &labels, m.requests);
            exp.point("pol_serve_predictions_total", &labels, m.predictions);
            exp.point("pol_serve_staleness_max", &labels, m.max_staleness);
            exp.histogram(
                "pol_serve_latency_ns",
                &labels,
                &HistogramSnapshot::from_latency(&m.latency),
            );
        }
    }
    if let Some(o) = &shared.obs {
        o.metrics.render_into(&mut exp);
    }
    exp.render()
}

/// Serve one connection to completion (see the module docs for the
/// close-vs-error-frame policy).
fn handle_conn(
    shared: &Shared,
    stream: TcpStream,
    poll: Duration,
    idle: Option<Duration>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(poll));
    // a peer that sends requests but never drains responses must not
    // wedge the handler in write_all: bound writes by the same
    // deadline that bounds idle reads (a timed-out write errors the
    // send and closes the connection)
    let _ = stream.set_write_timeout(idle);
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::with_capacity(1 << 16, stream);
    let mut writer = BufWriter::with_capacity(1 << 16, write_half);
    let mut buf = FrameBuf::new();
    let mut out = FrameWriter::new();
    let mut cache = ModelCache::new(&shared.registry);
    let mut scratch = BatchScratch::default();
    let mut preds: Vec<f64> = Vec::new();
    let mut local_stats: std::collections::HashMap<String, ModelStats> =
        std::collections::HashMap::new();
    let mut unflushed = 0u32;
    let mut drained = 0u32;
    loop {
        let draining = shared.stop.load(Ordering::Acquire);
        if draining {
            drained += 1;
            if drained > DRAIN_FRAMES {
                break;
            }
        }
        let idle_deadline = idle.map(|t| Instant::now() + t);
        match read_frame(
            &mut reader,
            &mut buf,
            Some(&shared.stop),
            idle_deadline,
        ) {
            Ok(None) => break, // clean close, or idle while draining
            Ok(Some(frame)) => {
                shared.frames_in.fetch_add(1, Ordering::Relaxed);
                shared
                    .bytes_in
                    .fetch_add(frame.wire_bytes as u64, Ordering::Relaxed);
                let op = frame.op;
                let req_id = frame.req_id;
                let enqueued = Instant::now();
                let outcome = match Op::from_u8(op) {
                    None => send_error(
                        shared,
                        &mut out,
                        &mut writer,
                        op,
                        STATUS_UNKNOWN_OP,
                        req_id,
                        &format!("unknown op {op}"),
                    ),
                    Some(kind @ (Op::Predict | Op::PredictBatch)) => {
                        match decode_predict_request(
                            kind,
                            frame.payload,
                            &mut scratch,
                        ) {
                            Ok(name) => {
                                match cache.resolve(&shared.registry, name) {
                                    Some((snap_reader, pscratch)) => {
                                        let snap =
                                            Arc::clone(snap_reader.current());
                                        preds.clear();
                                        for x in scratch.batch() {
                                            preds.push(
                                                snap.predict_with(x, pscratch),
                                            );
                                        }
                                        let staleness = snap_reader
                                            .cell()
                                            .staleness_of(&snap);
                                        out.start(op, STATUS_OK, req_id);
                                        put_predict_response(
                                            out.payload(),
                                            &preds,
                                            snap.version,
                                            staleness,
                                        );
                                        let sent = send_frame(
                                            shared,
                                            &mut out,
                                            &mut writer,
                                        );
                                        if sent.is_ok() {
                                            // private buffer: no lock,
                                            // no allocation once the
                                            // name has been seen
                                            match local_stats.get_mut(name)
                                            {
                                                Some(ms) => ms.record(
                                                    preds.len() as u64,
                                                    enqueued.elapsed(),
                                                    staleness,
                                                ),
                                                None => {
                                                    let mut ms =
                                                        ModelStats::new();
                                                    ms.record(
                                                        preds.len() as u64,
                                                        enqueued.elapsed(),
                                                        staleness,
                                                    );
                                                    local_stats.insert(
                                                        name.to_string(),
                                                        ms,
                                                    );
                                                }
                                            }
                                            unflushed += 1;
                                            if unflushed
                                                >= shared.stats_flush_frames
                                            {
                                                flush_stats(
                                                    shared,
                                                    &mut local_stats,
                                                );
                                                unflushed = 0;
                                            }
                                        }
                                        sent
                                    }
                                    None => send_error(
                                        shared,
                                        &mut out,
                                        &mut writer,
                                        op,
                                        STATUS_UNKNOWN_MODEL,
                                        req_id,
                                        &format!("unknown model '{name}'"),
                                    ),
                                }
                            }
                            Err(e) => {
                                shared
                                    .decode_errors
                                    .fetch_add(1, Ordering::Relaxed);
                                let status = match e {
                                    FrameError::OverCap(_) => {
                                        STATUS_TOO_LARGE
                                    }
                                    _ => STATUS_BAD_FRAME,
                                };
                                send_error(
                                    shared,
                                    &mut out,
                                    &mut writer,
                                    op,
                                    status,
                                    req_id,
                                    &e.to_string(),
                                )
                            }
                        }
                    }
                    Some(Op::Stats) => {
                        // publish this connection's own numbers first,
                        // so a client polling stats on the connection
                        // it queries through always sees itself
                        flush_stats(shared, &mut local_stats);
                        unflushed = 0;
                        out.start(op, STATUS_OK, req_id);
                        put_stats(out.payload(), &shared.stats());
                        send_frame(shared, &mut out, &mut writer)
                    }
                    Some(Op::MetricsDump) => {
                        if !frame.payload.is_empty() {
                            shared
                                .decode_errors
                                .fetch_add(1, Ordering::Relaxed);
                            send_error(
                                shared,
                                &mut out,
                                &mut writer,
                                op,
                                STATUS_BAD_FRAME,
                                req_id,
                                "metrics dump request carries a payload",
                            )
                        } else {
                            // same self-visibility rule as Stats: fold
                            // this connection's numbers in first
                            flush_stats(shared, &mut local_stats);
                            unflushed = 0;
                            out.start(op, STATUS_OK, req_id);
                            out.payload().extend_from_slice(
                                render_metrics(shared).as_bytes(),
                            );
                            send_frame(shared, &mut out, &mut writer)
                        }
                    }
                    Some(Op::ListModels) => {
                        let mut models = Vec::new();
                        for name in shared.registry.names() {
                            let Some(cell) = shared.registry.get(&name)
                            else {
                                continue; // removed between names() and get
                            };
                            let snap = cell.load();
                            models.push(ModelEntry {
                                name,
                                dim: snap.dim() as u64,
                                params: snap.num_params() as u64,
                                snapshot_version: snap.version,
                                trained_instances: snap.trained_instances,
                            });
                        }
                        out.start(op, STATUS_OK, req_id);
                        put_models(out.payload(), &models);
                        send_frame(shared, &mut out, &mut writer)
                    }
                    Some(Op::Ping) => {
                        if frame.payload.len() > MAX_PING {
                            send_error(
                                shared,
                                &mut out,
                                &mut writer,
                                op,
                                STATUS_TOO_LARGE,
                                req_id,
                                &format!(
                                    "ping payload {} bytes (cap {MAX_PING})",
                                    frame.payload.len()
                                ),
                            )
                        } else {
                            out.start(op, STATUS_OK, req_id);
                            out.payload().extend_from_slice(frame.payload);
                            send_frame(shared, &mut out, &mut writer)
                        }
                    }
                    Some(Op::Shutdown) => {
                        if shared.allow_remote_shutdown {
                            let sent = send_error(
                                shared,
                                &mut out,
                                &mut writer,
                                op,
                                STATUS_OK,
                                req_id,
                                "draining",
                            );
                            shared.trigger_stop();
                            sent
                        } else {
                            send_error(
                                shared,
                                &mut out,
                                &mut writer,
                                op,
                                STATUS_FORBIDDEN,
                                req_id,
                                "remote shutdown disabled on this server",
                            )
                        }
                    }
                };
                if outcome.is_err() {
                    break; // peer went away mid-write
                }
            }
            Err(FrameError::Io(_)) => break, // transport failure
            Err(_) => {
                // framing-level corruption: the stream cannot be
                // resynchronized, so count it and close cleanly —
                // never panic, never allocate toward a hostile length
                shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }
    flush_stats(shared, &mut local_stats);
    // a draining handler tells pipelined peers why the stream ends
    if shared.stop.load(Ordering::Acquire) {
        let _ = send_error(
            shared,
            &mut out,
            &mut writer,
            // pol-lint: allow(L006, "Op discriminants are u8 by definition")
            Op::Shutdown as u8,
            STATUS_SHUTTING_DOWN,
            0,
            "server draining",
        );
    }
}
