//! Per-connection byte-shuttling state for the readiness-driven
//! backend ([`crate::wire::poll`]): an accumulation buffer fed by
//! nonblocking partial reads, a pending-output buffer drained by
//! nonblocking partial writes, and the bookkeeping a multiplexed
//! connection needs (idle clock, private stats buffer, drain/close
//! flags).
//!
//! [`Conn`] is deliberately I/O-agnostic — [`Conn::fill`] and
//! [`Conn::drain_to`] are generic over `Read`/`Write` — so the
//! partial-read/partial-write/backpressure logic is unit-testable
//! against in-memory transports that yield `WouldBlock` at arbitrary
//! byte positions, which no real socket will do on demand.
//!
//! Buffer discipline (all caps are compile-time constants):
//!
//! * reads grow `rbuf` by at most [`READ_CHUNK`] per call — one
//!   connection cannot monopolize a wakeup by having a deep socket
//!   buffer;
//! * the loop stops reading a connection once `pending()` reaches
//!   [`RBUF_HIGH`] = `MAX_FRAME + 4`: at that size the buffer is
//!   *guaranteed* to hold either a complete frame or a framing error
//!   (no valid frame is larger), so decode always makes progress and
//!   flow control can never deadlock;
//! * the loop stops *decoding* (and reading) for a connection whose
//!   un-drained output reaches [`WBUF_HIGH`] — a peer that sends
//!   requests but never drains responses gets backpressure, not an
//!   unbounded server-side queue.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::time::Instant;

use crate::serve::server::ModelStats;
use crate::wire::frame::{FrameWriter, MAX_FRAME};

/// Most bytes one [`Conn::fill`] call reads — the per-connection,
/// per-wakeup read quantum.
pub(crate) const READ_CHUNK: usize = 1 << 14;

/// Stop reading a connection whose accumulation buffer holds this many
/// un-decoded bytes. `MAX_FRAME + 4` (prefix included) guarantees the
/// buffer then contains a complete frame or a framing error, so the
/// decode loop always makes progress against a backlogged peer.
pub(crate) const RBUF_HIGH: usize = MAX_FRAME as usize + 4;

/// Stop decoding for a connection whose pending output exceeds this —
/// write backpressure for peers that pipeline requests without
/// draining responses.
pub(crate) const WBUF_HIGH: usize = 1 << 18;

/// Compact `wbuf` (shift the un-written tail to the front) once the
/// dead prefix passes this, so a long-lived slow reader cannot pin an
/// ever-growing buffer.
const WBUF_COMPACT: usize = 1 << 16;

/// Compact `rbuf` once the consumed prefix passes this.
const RBUF_COMPACT: usize = 1 << 16;

/// What one [`Conn::fill`] observed on the transport.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum FillOutcome {
    /// `n > 0` fresh bytes appended to the accumulation buffer.
    Bytes(usize),
    /// The transport has nothing now (`WouldBlock`/`Interrupted`).
    NotReady,
    /// Orderly end of stream — the peer finished sending.
    Eof,
    /// Transport error: the connection is unusable.
    Gone,
}

/// What one [`Conn::drain_to`] left behind.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum DrainOutcome {
    /// Pending output fully written (or there was none).
    Drained,
    /// Output remains; `progressed` says whether any byte moved.
    Pending { progressed: bool },
    /// Transport error or zero-length write: the connection is gone.
    Gone,
}

/// One multiplexed connection's buffers and bookkeeping. Fields are
/// `pub(crate)` because the poll loop borrows them *disjointly* — the
/// decoded frame holds `rbuf` while the answer writes `wbuf`/`out` —
/// which field access allows and accessor methods would forbid.
pub(crate) struct Conn {
    /// Accumulated inbound bytes; `rbuf[rpos..]` is un-decoded.
    pub(crate) rbuf: Vec<u8>,
    /// Decode cursor into `rbuf`.
    pub(crate) rpos: usize,
    /// Pending outbound bytes; `wbuf[wpos..]` is un-written.
    pub(crate) wbuf: Vec<u8>,
    /// Write cursor into `wbuf`.
    pub(crate) wpos: usize,
    /// Recycled frame assembler for this connection's responses.
    pub(crate) out: FrameWriter,
    /// Last moment a complete frame was answered (connect time before
    /// any frame). Deliberately *not* advanced by partial reads: a
    /// slow-loris peer trickling bytes that never finish a frame ages
    /// toward the idle deadline exactly like a silent one, mirroring
    /// the threads backend's per-frame deadline.
    pub(crate) last_activity: Instant,
    /// This connection's private per-model stats buffer (merged into
    /// the shared map at cadence and on every close).
    pub(crate) local_stats: HashMap<String, ModelStats>,
    /// Frames answered since the last stats flush.
    pub(crate) unflushed: u32,
    /// Frames answered since drain began (bounded by
    /// [`crate::wire::server::DRAIN_FRAMES`]).
    pub(crate) drained: u32,
    /// No further reads or decodes; close once `wbuf` drains (or the
    /// loop's idle or drain-flush deadline passes — a peer that never
    /// reads its final bytes must not pin the slot).
    pub(crate) closing: bool,
    /// The peer half-closed its send side; answer what is buffered,
    /// then close.
    pub(crate) saw_eof: bool,
}

impl Conn {
    /// Fresh state for a connection admitted at `now`.
    pub(crate) fn new(now: Instant) -> Conn {
        Conn {
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            out: FrameWriter::new(),
            last_activity: now,
            local_stats: HashMap::new(),
            unflushed: 0,
            drained: 0,
            closing: false,
            saw_eof: false,
        }
    }

    /// The un-decoded inbound bytes.
    pub(crate) fn pending(&self) -> &[u8] {
        &self.rbuf[self.rpos..]
    }

    /// Bytes of output not yet written to the transport.
    pub(crate) fn write_backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Whether the loop should try reading this connection at all:
    /// not after EOF/close, and not past the [`RBUF_HIGH`] inbound or
    /// [`WBUF_HIGH`] outbound high-water marks (flow control).
    pub(crate) fn wants_fill(&self) -> bool {
        !self.saw_eof
            && !self.closing
            && self.pending().len() < RBUF_HIGH
            && self.write_backlog() < WBUF_HIGH
    }

    /// One bounded nonblocking read: grow `rbuf` by at most
    /// [`READ_CHUNK`], pull what the transport has, shrink back to the
    /// bytes actually received.
    pub(crate) fn fill(&mut self, r: &mut impl Read) -> FillOutcome {
        let old = self.rbuf.len();
        self.rbuf.resize(old + READ_CHUNK, 0);
        let got = r.read(&mut self.rbuf[old..]);
        match got {
            Ok(0) => {
                self.rbuf.truncate(old);
                self.saw_eof = true;
                FillOutcome::Eof
            }
            Ok(n) => {
                self.rbuf.truncate(old + n);
                FillOutcome::Bytes(n)
            }
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                ) =>
            {
                self.rbuf.truncate(old);
                FillOutcome::NotReady
            }
            Err(_) => {
                self.rbuf.truncate(old);
                FillOutcome::Gone
            }
        }
    }

    /// Mark `n` bytes at the front of [`Conn::pending`] decoded, and
    /// compact the buffer when the dead prefix is the whole buffer (the
    /// common pipelining case — backlog fully drained) or has grown
    /// past [`RBUF_COMPACT`].
    pub(crate) fn consume(&mut self, n: usize) {
        self.rpos += n;
        debug_assert!(self.rpos <= self.rbuf.len());
        if self.rpos == self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        } else if self.rpos >= RBUF_COMPACT {
            self.rbuf.copy_within(self.rpos.., 0);
            let live = self.rbuf.len() - self.rpos;
            self.rbuf.truncate(live);
            self.rpos = 0;
        }
    }

    /// One nonblocking write pass over the pending output. Loops while
    /// the transport accepts bytes; stops at `WouldBlock`. `Ok(0)` from
    /// a nonblocking socket write means the peer is gone.
    pub(crate) fn drain_to(&mut self, w: &mut impl Write) -> DrainOutcome {
        let mut progressed = false;
        while self.wpos < self.wbuf.len() {
            match w.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return DrainOutcome::Gone,
                Ok(n) => {
                    self.wpos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return DrainOutcome::Gone,
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
            DrainOutcome::Drained
        } else {
            if self.wpos >= WBUF_COMPACT {
                self.wbuf.copy_within(self.wpos.., 0);
                let live = self.wbuf.len() - self.wpos;
                self.wbuf.truncate(live);
                self.wpos = 0;
            }
            DrainOutcome::Pending { progressed }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reader that hands out its script one slice per call, then
    /// yields `WouldBlock` forever (or EOF, when `eof` is set).
    struct ScriptedReader {
        chunks: Vec<Vec<u8>>,
        eof: bool,
    }

    impl Read for ScriptedReader {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let Some(c) = self.chunks.first() {
                let n = c.len().min(buf.len());
                buf[..n].copy_from_slice(&c[..n]);
                if n == c.len() {
                    self.chunks.remove(0);
                } else {
                    self.chunks[0].drain(..n);
                }
                return Ok(n);
            }
            if self.eof {
                Ok(0)
            } else {
                Err(io::Error::from(io::ErrorKind::WouldBlock))
            }
        }
    }

    /// A writer that accepts at most `cap` bytes per call and yields
    /// `WouldBlock` every other call — the adversarial partial-write
    /// transport.
    struct TricklingWriter {
        cap: usize,
        wrote: Vec<u8>,
        turn: bool,
    }

    impl Write for TricklingWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.turn = !self.turn;
            if !self.turn {
                return Err(io::Error::from(io::ErrorKind::WouldBlock));
            }
            let n = buf.len().min(self.cap);
            self.wrote.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn fill_accumulates_partial_reads_and_flags_eof() {
        let mut c = Conn::new(Instant::now());
        let mut r = ScriptedReader {
            chunks: vec![vec![1, 2, 3], vec![4, 5]],
            eof: true,
        };
        assert_eq!(c.fill(&mut r), FillOutcome::Bytes(3));
        assert_eq!(c.fill(&mut r), FillOutcome::Bytes(2));
        assert_eq!(c.pending(), &[1, 2, 3, 4, 5]);
        assert_eq!(c.fill(&mut r), FillOutcome::Eof);
        assert!(c.saw_eof);
        // rbuf never keeps the zero padding past the received bytes
        assert_eq!(c.rbuf.len(), 5);
    }

    #[test]
    fn fill_reports_not_ready_without_growing_the_buffer() {
        let mut c = Conn::new(Instant::now());
        let mut r = ScriptedReader { chunks: vec![], eof: false };
        assert_eq!(c.fill(&mut r), FillOutcome::NotReady);
        assert!(c.pending().is_empty());
        assert_eq!(c.rbuf.len(), 0);
    }

    #[test]
    fn consume_advances_and_compacts_at_the_boundary() {
        let mut c = Conn::new(Instant::now());
        c.rbuf = vec![9; 10];
        c.consume(4);
        assert_eq!(c.pending().len(), 6);
        c.consume(6);
        // fully consumed: buffer resets so steady state never grows
        assert_eq!(c.rbuf.len(), 0);
        assert_eq!(c.rpos, 0);
    }

    #[test]
    fn drain_survives_would_block_and_partial_writes() {
        let mut c = Conn::new(Instant::now());
        c.wbuf = (0u8..100).collect();
        let mut w = TricklingWriter { cap: 7, wrote: Vec::new(), turn: false };
        let mut passes = 0;
        loop {
            match c.drain_to(&mut w) {
                DrainOutcome::Drained => break,
                DrainOutcome::Pending { .. } => passes += 1,
                DrainOutcome::Gone => panic!("transport declared dead"),
            }
            assert!(passes < 1000, "drain must terminate");
        }
        assert_eq!(w.wrote, (0u8..100).collect::<Vec<u8>>());
        assert_eq!(c.write_backlog(), 0);
        assert_eq!(c.wbuf.len(), 0);
    }

    #[test]
    fn drain_treats_zero_write_as_gone() {
        struct DeadWriter;
        impl Write for DeadWriter {
            fn write(&mut self, _b: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut c = Conn::new(Instant::now());
        c.wbuf = vec![1, 2, 3];
        assert_eq!(c.drain_to(&mut DeadWriter), DrainOutcome::Gone);
    }

    #[test]
    fn flow_control_stops_reads_at_the_high_water_marks() {
        let mut c = Conn::new(Instant::now());
        assert!(c.wants_fill());
        c.rbuf = vec![0; RBUF_HIGH];
        assert!(!c.wants_fill(), "inbound high-water mark must gate reads");
        c.rbuf.clear();
        c.wbuf = vec![0; WBUF_HIGH];
        assert!(!c.wants_fill(), "write backpressure must gate reads");
        c.wbuf.clear();
        c.saw_eof = true;
        assert!(!c.wants_fill(), "no reads after EOF");
    }
}
