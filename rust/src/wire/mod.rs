//! `pol::wire` — the real network front-end: a length-prefixed binary
//! protocol, a TCP server over the serving registry, a blocking
//! client, and an admin plane.
//!
//! The paper's multinode story (§0.5.3) is shaped by network behaviour
//! — "the use of many small packets can result in substantially
//! reduced bandwidth" — and this module applies that lesson to the
//! serving path: many predictions batch into one frame, one checksum,
//! one syscall each way. [`crate::net`] *simulates* that wire for the
//! training-time experiments; `pol::wire` is the deployable one,
//! pure `std` like the rest of the crate.
//!
//! * [`frame`] — the versioned envelope. Layout (little-endian):
//!
//!   | offset | size | field    | notes                               |
//!   |--------|------|----------|-------------------------------------|
//!   | 0      | 4    | len      | body bytes; 24 ≤ len ≤ 4 MiB        |
//!   | 4      | 4    | magic    | `POLW`                              |
//!   | 8      | 2    | version  | protocol version (1)                |
//!   | 10     | 1    | op       | Predict, PredictBatch, Stats, ListModels, Ping, Shutdown, MetricsDump, MetricsHistory |
//!   | 11     | 1    | status   | 0 = request/ok; error code on responses |
//!   | 12     | 8    | req_id   | echoed in the response              |
//!   | 20     | n    | payload  | op-specific                         |
//!   | 20 + n | 8    | checksum | FNV-1a64 over magic..payload        |
//!
//!   Strict caps (frame size, batch size, features per instance, name
//!   and ping lengths) are enforced *before* any allocation, so a
//!   hostile peer can never make either side allocate past one frame —
//!   the same discipline as the `.polz` codec.
//! * [`server`] — [`WireServer`]: one handle, two I/O backends
//!   (selected by [`WireConfig::io_model`]), both driving the **same**
//!   [`crate::serve::ModelRegistry`]/[`crate::serve::SnapshotCell`]
//!   read path as the in-process [`crate::serve::PredictionServer`]
//!   (per-connection cached `(reader, scratch)` through
//!   [`crate::serve::ModelCache`] — zero steady-state allocation),
//!   per-model routing by name, request pipelining, graceful drain,
//!   an idle-connection/slow-loris deadline, an optional
//!   remote-shutdown lockout, and wire-level stats. With
//!   [`WireConfig::obs`] attached, the `MetricsDump` op exports the
//!   whole process's metrics registry in the `# pol-metrics v1` text
//!   format (see [`crate::obs`]) — what `pol top`/`pol metrics`
//!   scrape — and the shared dispatch records per-phase request
//!   timing (`pol_wire_phase_ns{phase,op}`, see [`crate::obs::span`])
//!   for both backends from the one instrumentation point. The
//!   `MetricsHistory` op returns the server's own bounded ring of
//!   periodic registry snapshots (`history_every`/`history_len` in
//!   [`WireConfig`]; see [`crate::obs::series`]), payload layout
//!   `u32 nsnaps` then per snapshot
//!   `u64 tick | u64 uptime_ms | u32 nseries` followed by `nseries` ×
//!   (`u16 name_len | name | u64 value`) — every count checked against
//!   a cap *before* any allocation. With [`WireConfig::flight_path`]
//!   set, shutdown serializes the trace tail + snapshot history +
//!   config digest into a versioned `.poltrace` flight record
//!   ([`crate::obs::flight`]), readable offline by `pol trace FILE`.
//! * [`poll`] + [`conn`] — the readiness-driven backend
//!   ([`IoModel::Poll`]): one event loop multiplexing every
//!   connection over nonblocking sockets, with per-connection
//!   buffered state machines ([`conn`]) and a pure-`std` readiness
//!   shim ([`Poller`]/[`ScanPoller`]).
//! * [`client`] — [`WireClient`]: blocking, one reused connection,
//!   single/batch/pipelined predict (bounded in-flight window, so
//!   arbitrarily long request streams cannot deadlock the socket
//!   buffers) plus the admin ops, every failure a typed [`WireError`]
//!   — and responses are shape-checked, so a misbehaving peer yields
//!   an error, never a panic.
//!
//! # Picking an I/O model
//!
//! **`threads`** (the default): a bounded handler pool, one blocking
//! thread per active connection. Lowest latency for a few busy,
//! long-lived peers (a dedicated thread blocks directly on the
//! socket); concurrency is capped at the pool size, and connections
//! past it wait *unserved* in the kernel accept backlog — mostly-idle
//! peers monopolize handlers.
//!
//! **`poll`**: one readiness loop multiplexing every connection
//! ([`poll`] module docs have the mechanics). Thousands of
//! mostly-idle connections cost no threads; concurrency is capped by
//! [`WireConfig::max_conns`] *admission control*, not thread count.
//! Pick it whenever connection count exceeds a sane thread count —
//! the production posture for "millions of users" traffic.
//!
//! Overload semantics differ on purpose. The threads backend queues
//! excess connections in the accept backlog (invisible until the
//! kernel drops them). The poll backend is explicit: a connection
//! past `max_conns` is **shed** — it receives one typed
//! over-capacity frame ([`Op::Shutdown`] op byte, `TOO_LARGE`
//! status, request id 0; surfaced by [`WireClient`] as a typed
//! server error) and is closed, the `pol_wire_conns_shed` counter
//! ticks, and every *admitted* connection keeps answering.
//! Per-connection fairness comes from [`WireConfig::frame_budget`]:
//! at most that many frames are answered per connection per loop
//! sweep, so a max-rate pipelining peer cannot starve a slow one.
//!
//! Both backends answer through one shared dispatch, so every
//! response byte — prediction bits included — is identical between
//! them; the test suite runs against both (`POL_WIRE_IO` selects the
//! backend matrix in CI).
//!
//! ```no_run
//! use std::sync::Arc;
//! use pol::prelude::*;
//! use pol::wire::{WireClient, WireConfig, WireServer};
//!
//! // serve a checkpointed model over TCP…
//! let model = pol::model::load("model.polz").expect("load");
//! let registry = ModelRegistry::with_model("m", SnapshotCell::new(model.snapshot()));
//! let server = WireServer::bind("127.0.0.1:0", Arc::clone(&registry), WireConfig::default())
//!     .expect("bind");
//!
//! // …and query it from anywhere
//! let mut client = WireClient::connect(server.local_addr()).expect("connect");
//! let resp = client.predict_for("m", &[(0, 1.0), (7, -0.5)]).expect("predict");
//! println!("pred {} (snapshot v{}, {} instances behind)",
//!          resp.preds[0], resp.snapshot_version, resp.staleness);
//! server.shutdown();
//! ```

/// Blocking client for the framed protocol.
pub mod client;
/// Per-connection buffered state for the readiness backend.
pub mod conn;
/// Frame format: header, opcodes, payload codecs.
pub mod frame;
/// Readiness event loop + pure-`std` poller shim.
pub mod poll;
/// TCP server speaking the framed protocol.
pub mod server;

pub use client::{WireClient, WireError, WireResponse};
pub use frame::{
    FrameError, ModelEntry, ModelStatsReport, Op, StatsReport, MAX_BATCH,
    MAX_FEATURES, MAX_FRAME, MAX_NAME, MAX_PING, PROTO_VERSION,
};
pub use poll::{Poller, ScanPoller, DRAIN_FLUSH};
pub use server::{
    IoModel, WireConfig, WireServer, DEFAULT_FRAME_BUDGET,
    DEFAULT_MAX_CONNS, DEFAULT_STATS_FLUSH_FRAMES, DRAIN_FRAMES,
};
