//! The readiness-driven wire backend ([`IoModel::Poll`]): one loop,
//! nonblocking sockets, every connection multiplexed.
//!
//! [`IoModel::Poll`]: crate::wire::server::IoModel::Poll
//!
//! # Shape
//!
//! [`PollServer::run`] owns the listener and every admitted connection
//! and sweeps them per wakeup:
//!
//! 1. **accept** — drain the nonblocking accept queue. A connection
//!    past the admission cap is *shed*: it gets one typed
//!    over-capacity frame ([`Op::Shutdown`] op byte,
//!    [`STATUS_TOO_LARGE`]) and an immediate close, and the
//!    `pol_wire_conns_shed` counter ticks — overload is explicit, not
//!    a silently collapsing queue.
//! 2. **per connection** — write-drain pending output, then read up
//!    to one [`crate::wire::conn::READ_CHUNK`], then decode and
//!    answer at most `frame_budget` frames. The budget is the
//!    fairness mechanism: a peer streaming max-rate pipelined frames
//!    is preempted after `frame_budget` answers and the sweep moves
//!    on, so a slow peer's single frame is never stuck behind an
//!    unbounded burst.
//! 3. **sleep** — only when a full sweep made no progress anywhere
//!    (no bytes moved, no frames answered, no state change), for the
//!    configured poll interval.
//!
//! Answers come from the same [`answer_frame`] dispatch the threads
//! backend runs, writing into the connection's pending-output buffer
//! (`Vec<u8>` implements `io::Write`; the flush inside `send_frame`
//! is a no-op there) — prediction bytes are bit-identical across
//! backends by construction.
//!
//! # Readiness without `poll(2)`
//!
//! The crate confines `unsafe` to the kernel layer (lint rule L007 —
//! not waivable elsewhere), and `std` exposes no readiness syscall,
//! so the [`Poller`] trait is the platform seam: [`ScanPoller`], the
//! pure-`std` implementation used today, reports "probe everything"
//! and relies on nonblocking reads/writes returning `WouldBlock` as
//! the per-source readiness verdict, sleeping the poll interval only
//! when a whole sweep is idle. An OS-backed `poll(2)`/`epoll`
//! implementation slots in behind the same trait (wait returns the
//! ready tokens; the sweep then probes only those) the day an FFI
//! story exists — nothing above this module changes.
//!
//! # Deadlines, drain, stats
//!
//! Idle and slow-loris peers age out against `idle_timeout`: a
//! connection's clock only advances when a *complete* frame is
//! answered, so trickling bytes that never finish a frame is
//! indistinguishable from silence, mirroring the threads backend's
//! per-frame read deadline. The same deadline covers the write
//! direction: a connection lingering in the closing state because its
//! peer never reads the final responses ages out too (the threads
//! backend gets this from its idle-bounded write timeout), so a
//! half-closed, never-reading peer cannot pin a conn slot and bleed
//! the admission cap. On shutdown the loop stops accepting, answers
//! only the frames already buffered per connection (bounded by
//! [`DRAIN_FRAMES`]), enqueues the typed shutting-down frame, and
//! closes each connection as its output drains — with [`DRAIN_FLUSH`]
//! as the hard bound on *every* connection, including one stuck in
//! write backpressure that never reached the closing state. Every
//! close — idle, EOF, error, shed-free drain — flushes the
//! connection's private stats buffer into the shared map first, the
//! same disconnect-flush contract the threads backend keeps.

// Every Relaxed here is monotonic telemetry (shed/wakeup/byte/frame
// counters, the active gauge); real cross-thread hand-off goes through
// the `stop` flag's Acquire/Release pair and the stats mutex.
// pol-lint: allow-file(L002, "wire counters are monotonic telemetry")

use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::LockExt;
use crate::wire::conn::{Conn, DrainOutcome, FillOutcome, WBUF_HIGH};
use crate::wire::frame::{
    decode_frame_from, FrameWriter, Op, STATUS_SHUTTING_DOWN,
    STATUS_TOO_LARGE,
};
use crate::wire::server::{
    answer_frame, flush_stats, send_goodbye, HandlerCtx, Shared,
    DRAIN_FRAMES,
};

/// How long a draining [`PollServer`] keeps lingering connections
/// around to flush their final output before force-closing them.
pub const DRAIN_FLUSH: Duration = Duration::from_secs(5);

/// The platform seam for readiness notification. Implementations tell
/// the event loop *which* registered sources to probe after a wait.
///
/// `std` has no readiness syscall and lint rule L007 keeps `unsafe`
/// (hence FFI) out of this layer, so the shipped implementation is the
/// probe-based [`ScanPoller`]; an OS `poll(2)`/`epoll` backend belongs
/// behind this same trait.
pub trait Poller {
    /// Track a new readiness source under `token`.
    fn register(&mut self, token: usize);
    /// Stop tracking `token`.
    fn deregister(&mut self, token: usize);
    /// Block up to `timeout` for readiness. `None` means "no
    /// per-source information — probe every registered source";
    /// `Some(tokens)` narrows the next sweep to those sources.
    fn wait(&mut self, timeout: Duration) -> Option<Vec<usize>>;
}

/// Pure-`std` [`Poller`]: no readiness syscall, so every wait reports
/// "probe everything" and the loop discovers per-source readiness from
/// nonblocking calls returning `WouldBlock`. The wait itself is a
/// plain sleep — it only runs when a full sweep made no progress, so
/// the loop idles at the poll interval instead of spinning.
pub struct ScanPoller {
    registered: usize,
}

impl ScanPoller {
    /// A poller tracking nothing.
    pub fn new() -> ScanPoller {
        ScanPoller { registered: 0 }
    }

    /// How many sources are currently registered.
    pub fn registered(&self) -> usize {
        self.registered
    }
}

impl Default for ScanPoller {
    fn default() -> Self {
        ScanPoller::new()
    }
}

impl Poller for ScanPoller {
    fn register(&mut self, _token: usize) {
        self.registered += 1;
    }

    fn deregister(&mut self, _token: usize) {
        self.registered = self.registered.saturating_sub(1);
    }

    fn wait(&mut self, timeout: Duration) -> Option<Vec<usize>> {
        std::thread::sleep(timeout);
        None
    }
}

/// Tuning handed from [`crate::wire::server::WireConfig`] to the loop.
pub(crate) struct PollParams {
    /// Sleep between sweeps that made no progress.
    pub(crate) poll: Duration,
    /// Idle/slow-loris deadline per connection (`None` = never).
    pub(crate) idle_timeout: Option<Duration>,
    /// Admission cap: connections tracked at once; excess is shed.
    pub(crate) max_conns: usize,
    /// Frames answered per connection per sweep (fairness quantum).
    pub(crate) frame_budget: u32,
}

/// One admitted connection: its socket, its readiness token, and its
/// buffered state machine.
struct PollConn {
    token: usize,
    stream: TcpStream,
    conn: Conn,
}

/// What one [`PollServer::service`] pass decided for a connection.
enum Verdict {
    /// Keep the connection; `progressed`/`frames` feed the sweep's
    /// progress flag and the per-wakeup frames histogram.
    Keep { progressed: bool, frames: u32 },
    /// Remove and close the connection (stats flush first).
    Close,
}

/// The readiness event loop (see the module docs). Constructed and run
/// on the dedicated `wire-poll` thread by
/// [`crate::wire::server::WireServer::bind`].
pub(crate) struct PollServer {
    shared: Arc<Shared>,
    listener: TcpListener,
    poller: ScanPoller,
    conns: Vec<PollConn>,
    ctx: HandlerCtx,
    params: PollParams,
    next_token: usize,
    drain_deadline: Option<Instant>,
    shed_frame: Vec<u8>,
}

impl PollServer {
    /// Wrap an already-bound listener. The shed frame is precomputed
    /// once so overload handling allocates nothing per refused peer.
    pub(crate) fn new(
        shared: Arc<Shared>,
        listener: TcpListener,
        params: PollParams,
    ) -> PollServer {
        // best-effort: if the platform refused nonblocking mode the
        // stop-wake connection still unblocks a stuck accept
        let _ = listener.set_nonblocking(true);
        let mut out = FrameWriter::new();
        out.start(
            // pol-lint: allow(L006, "Op discriminants are u8 by definition")
            Op::Shutdown as u8,
            STATUS_TOO_LARGE,
            0,
        );
        out.payload()
            .extend_from_slice(b"server over capacity: connection shed");
        let mut shed_frame = Vec::new();
        // writing to a Vec cannot fail
        let _ = out.finish_to(&mut shed_frame);
        let ctx = HandlerCtx::new(&shared);
        PollServer {
            shared,
            listener,
            poller: ScanPoller::new(),
            conns: Vec::new(),
            ctx,
            params,
            next_token: 0,
            drain_deadline: None,
            shed_frame,
        }
    }

    /// Run until a drain is requested and every connection has closed.
    pub(crate) fn run(mut self) {
        loop {
            let now = Instant::now();
            let draining = self.shared.stop.load(Ordering::Acquire);
            if draining && self.drain_deadline.is_none() {
                self.drain_deadline = Some(now + DRAIN_FLUSH);
            }
            if !draining {
                self.accept_new(now);
            }
            let mut progressed = false;
            let mut total_frames = 0u64;
            let mut i = 0;
            while i < self.conns.len() {
                match self.service(i, now, draining) {
                    Verdict::Close => {
                        self.close_at(i);
                        progressed = true;
                        // swap_remove moved a fresh conn into slot i
                    }
                    Verdict::Keep { progressed: p, frames } => {
                        progressed |= p;
                        total_frames += u64::from(frames);
                        i += 1;
                    }
                }
            }
            self.shared.wakeups.fetch_add(1, Ordering::Relaxed);
            {
                // per-wakeup frames-answered histogram (fairness
                // budget observability); idle sweeps record zeros
                let mut wf =
                    self.shared.wakeup_frames.lock().recover_poisoned();
                wf.record(total_frames);
            }
            if draining && self.conns.is_empty() {
                break;
            }
            if !progressed {
                let _ = self.poller.wait(self.params.poll);
            }
        }
    }

    /// Drain the nonblocking accept queue: admit up to the cap, shed
    /// the rest with the typed over-capacity frame.
    fn accept_new(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.shared.stop.load(Ordering::Acquire) {
                        // trigger_stop's throwaway wake connection:
                        // never counted, exactly like the threads
                        // acceptor's post-accept stop check
                        return;
                    }
                    if self.conns.len() >= self.params.max_conns {
                        self.shed(stream);
                        continue;
                    }
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    self.shared.connections.fetch_add(1, Ordering::Relaxed);
                    self.shared.active.fetch_add(1, Ordering::Relaxed);
                    let token = self.next_token;
                    self.next_token += 1;
                    self.poller.register(token);
                    self.conns.push(PollConn {
                        token,
                        stream,
                        conn: Conn::new(now),
                    });
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                // transient accept failure (EMFILE under a flood):
                // retry next sweep instead of hot-looping
                Err(_) => return,
            }
        }
    }

    /// Refuse one over-cap connection: count it, best-effort write the
    /// precomputed typed frame, close. The frame is a handful of bytes
    /// into an empty socket buffer, so the single nonblocking write
    /// virtually always lands whole; a peer that raced away simply
    /// misses its goodbye.
    fn shed(&mut self, stream: TcpStream) {
        // the threads backend counts every accept in `connections`;
        // a shed accept counts there too, so the two backends report
        // identical pol_wire_connections_total for identical traffic
        self.shared.connections.fetch_add(1, Ordering::Relaxed);
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
        let _ = stream.set_nonblocking(true);
        let mut w = &stream;
        if w.write_all(&self.shed_frame).is_ok() {
            self.shared.frames_out.fetch_add(1, Ordering::Relaxed);
            self.shared
                .bytes_out
                .fetch_add(self.shed_frame.len() as u64, Ordering::Relaxed);
        }
        // stream drops here: FIN right behind the frame
    }

    /// One service pass over connection `i`: write-drain, deadlines,
    /// read, then decode/answer up to the fairness budget.
    fn service(&mut self, i: usize, now: Instant, draining: bool) -> Verdict {
        let pc = &mut self.conns[i];
        let mut progressed = false;

        // pending output first — a readiness loop must never let
        // decode work starve half-written responses
        let wrote = {
            let mut w = &pc.stream;
            pc.conn.drain_to(&mut w)
        };
        match wrote {
            DrainOutcome::Gone => return Verdict::Close,
            DrainOutcome::Drained => {}
            DrainOutcome::Pending { progressed: p } => progressed |= p,
        }

        // drain flush bound: past the deadline *every* connection is
        // force-closed — closing or still under write backpressure —
        // so shutdown() is bounded by DRAIN_FLUSH, never by a peer
        // that stopped reading
        if self.drain_deadline.is_some_and(|d| now >= d) {
            return Verdict::Close;
        }

        // idle/slow-loris deadline: the clock only advances on
        // answered frames, so byte-trickling ages out. Checked before
        // the closing branch on purpose — a peer that half-closes with
        // responses pending and never reads them must not pin a conn
        // slot past the deadline (the write-direction guard the
        // threads backend gets from its idle-bounded write timeout).
        if let Some(idle) = self.params.idle_timeout {
            if now.duration_since(pc.conn.last_activity) >= idle {
                return Verdict::Close;
            }
        }

        // a closing connection only lingers for its final bytes
        if pc.conn.closing {
            if pc.conn.write_backlog() == 0 {
                return Verdict::Close;
            }
            return Verdict::Keep { progressed, frames: 0 };
        }

        // read one bounded chunk (never while draining: shutdown
        // answers only what was already buffered)
        if !draining && pc.conn.wants_fill() {
            let got = {
                let mut r = &pc.stream;
                pc.conn.fill(&mut r)
            };
            match got {
                FillOutcome::Bytes(_) | FillOutcome::Eof => progressed = true,
                FillOutcome::NotReady => {}
                FillOutcome::Gone => return Verdict::Close,
            }
        }

        // decode and answer up to the fairness budget
        let mut frames = 0u32;
        let mut backlog_empty = false;
        while frames < self.params.frame_budget {
            if draining && pc.conn.drained >= DRAIN_FRAMES {
                break; // bounded drain: stop answering
            }
            if pc.conn.write_backlog() >= WBUF_HIGH {
                break; // write backpressure: answers wait for drain
            }
            match decode_frame_from(&pc.conn.rbuf[pc.conn.rpos..]) {
                Ok(None) => {
                    backlog_empty = true;
                    break;
                }
                Ok(Some((frame, total))) => {
                    self.shared.frames_in.fetch_add(1, Ordering::Relaxed);
                    self.shared
                        .bytes_in
                        .fetch_add(total as u64, Ordering::Relaxed);
                    if draining {
                        pc.conn.drained += 1;
                    }
                    let sent = answer_frame(
                        &self.shared,
                        &frame,
                        &mut self.ctx,
                        &mut pc.conn.out,
                        &mut pc.conn.wbuf,
                        &mut pc.conn.local_stats,
                        &mut pc.conn.unflushed,
                    );
                    pc.conn.consume(total);
                    if sent.is_err() {
                        // unreachable for a Vec sink, but the contract
                        // is "send failure closes the connection"
                        return Verdict::Close;
                    }
                    pc.conn.last_activity = now;
                    frames += 1;
                    progressed = true;
                }
                Err(_) => {
                    // framing corruption: the byte stream cannot be
                    // resynchronized — count and close, same policy
                    // (and same counter) as the threads backend
                    self.shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                    return Verdict::Close;
                }
            }
        }

        if draining {
            // buffered frames answered (or the drain cap hit): tell
            // pipelined peers why the stream ends, then linger only
            // for the output to flush
            if backlog_empty || pc.conn.drained >= DRAIN_FRAMES {
                let _ = send_goodbye(
                    &self.shared,
                    &mut pc.conn.out,
                    &mut pc.conn.wbuf,
                    STATUS_SHUTTING_DOWN,
                    "server draining",
                );
                pc.conn.closing = true;
                progressed = true;
            }
        } else if pc.conn.saw_eof && backlog_empty {
            if pc.conn.rpos < pc.conn.rbuf.len() {
                // EOF inside a frame: truncation, counted like the
                // threads backend's mid-frame EOF
                self.shared.decode_errors.fetch_add(1, Ordering::Relaxed);
                return Verdict::Close;
            }
            if pc.conn.write_backlog() == 0 {
                return Verdict::Close; // clean close at a boundary
            }
            pc.conn.closing = true; // flush the tail, then close
        }

        Verdict::Keep { progressed, frames }
    }

    /// Close and forget connection `i` — flushing its private stats
    /// into the shared map *first*, the same disconnect-flush contract
    /// the threads backend keeps (idle-timeout and shed-drain closes
    /// included).
    fn close_at(&mut self, i: usize) {
        let mut pc = self.conns.swap_remove(i);
        flush_stats(&self.shared, &mut pc.conn.local_stats);
        self.poller.deregister(pc.token);
        self.shared.active.fetch_sub(1, Ordering::Relaxed);
        // pc.stream drops here, closing the socket
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64};
    use std::sync::Mutex;

    use crate::obs::{HistogramSnapshot, SeriesRing};
    use crate::serve::ModelRegistry;

    fn test_shared(local_addr: std::net::SocketAddr) -> Arc<Shared> {
        Arc::new(Shared {
            registry: ModelRegistry::new(),
            stop: AtomicBool::new(false),
            allow_remote_shutdown: true,
            local_addr,
            started: Instant::now(),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            active: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            wakeups: AtomicU64::new(0),
            wakeup_frames: Mutex::new(HistogramSnapshot::default()),
            per_model: Mutex::new(std::collections::BTreeMap::new()),
            stats_flush_frames: 64,
            obs: None,
            history: Arc::new(SeriesRing::new(4)),
            config_digest: 0,
            flight_path: None,
        })
    }

    /// A server with one tracked connection whose peer never reads,
    /// carrying `backlog` bytes of pending output. The backlog is far
    /// past any kernel buffer, so a drain pass cannot finish it — the
    /// connection stays pending by construction.
    fn server_with_stuck_conn(
        idle_timeout: Option<Duration>,
        backlog: usize,
    ) -> (PollServer, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let peer = TcpStream::connect(addr).expect("connect");
        let (stream, _) = listener.accept().expect("accept");
        let _ = stream.set_nonblocking(true);
        let mut srv = PollServer::new(
            test_shared(addr),
            listener,
            PollParams {
                poll: Duration::from_millis(1),
                idle_timeout,
                max_conns: 4,
                frame_budget: 16,
            },
        );
        let mut conn = Conn::new(Instant::now());
        conn.wbuf = vec![0xAB; backlog];
        srv.conns.push(PollConn { token: 0, stream, conn });
        (srv, peer)
    }

    /// REVIEW regression (high): a connection in the closing state —
    /// peer half-closed, responses pending, peer never reads — must
    /// age out against the idle deadline instead of pinning a conn
    /// slot forever and bleeding the admission cap.
    #[test]
    fn closing_connection_whose_peer_never_reads_hits_the_idle_deadline() {
        let idle = Duration::from_secs(5);
        let (mut srv, _peer) =
            server_with_stuck_conn(Some(idle), 64 << 20);
        srv.conns[0].conn.closing = true;

        // inside the deadline the closing connection lingers for its
        // final bytes, exactly as before
        assert!(
            matches!(
                srv.service(0, Instant::now(), false),
                Verdict::Keep { .. }
            ),
            "a closing conn inside the idle deadline must be kept"
        );
        assert!(
            srv.conns[0].conn.write_backlog() > 0,
            "test invariant: the peer must not have drained the backlog"
        );

        // past the deadline it goes, pending output or not
        let stale = Instant::now()
            .checked_sub(idle + Duration::from_millis(1))
            .expect("clock headroom");
        srv.conns[0].conn.last_activity = stale;
        assert!(
            matches!(srv.service(0, Instant::now(), false), Verdict::Close),
            "a closing conn past the idle deadline must be closed"
        );
    }

    /// REVIEW regression (medium): during a drain, a connection stuck
    /// at the write high-water mark never reaches the closing state
    /// (the decode loop breaks before `backlog_empty`), so the
    /// DRAIN_FLUSH force-close must apply to it directly — otherwise
    /// shutdown() blocks on the slowest reader instead of the
    /// documented flush bound.
    #[test]
    fn drain_deadline_force_closes_connections_stuck_in_backpressure() {
        let (mut srv, _peer) =
            server_with_stuck_conn(None, WBUF_HIGH + (64 << 20));
        srv.shared.stop.store(true, Ordering::Release);

        // before the flush deadline the connection is kept (it may
        // still drain on its own) — and the bug's precondition holds:
        // backpressure kept it out of the closing state
        let now = Instant::now();
        srv.drain_deadline = Some(now + DRAIN_FLUSH);
        assert!(
            matches!(srv.service(0, now, true), Verdict::Keep { .. }),
            "inside the flush deadline the conn may still drain"
        );
        assert!(
            !srv.conns[0].conn.closing,
            "test invariant: backpressure must have kept the conn \
             out of the closing state"
        );
        assert!(
            srv.conns[0].conn.write_backlog() >= WBUF_HIGH,
            "test invariant: the backlog must still be above the \
             high-water mark"
        );

        // at the deadline the force-close fires even though the
        // connection never reached the closing state
        let later = now + DRAIN_FLUSH;
        assert!(
            matches!(srv.service(0, later, true), Verdict::Close),
            "the drain flush deadline must bound a backpressured conn"
        );
    }

    #[test]
    fn scan_poller_tracks_registration_and_reports_probe_all() {
        let mut p = ScanPoller::new();
        assert_eq!(p.registered(), 0);
        p.register(7);
        p.register(9);
        assert_eq!(p.registered(), 2);
        p.deregister(7);
        assert_eq!(p.registered(), 1);
        // no readiness syscall: a wait always says "probe everything"
        assert_eq!(p.wait(Duration::from_millis(1)), None);
        p.deregister(9);
        p.deregister(9); // double-deregister must not underflow
        assert_eq!(p.registered(), 0);
    }
}
