//! The `pol` wire frame: a versioned, length-prefixed, checksummed
//! binary envelope — the paper's small-packet lesson ("the use of many
//! small packets can result in substantially reduced bandwidth",
//! §0.5.3) applied to serving: many predictions batch into ONE frame.
//!
//! Layout (all integers little-endian):
//!
//! | offset   | size | field    | notes                                |
//! |----------|------|----------|--------------------------------------|
//! | 0        | 4    | len      | bytes after this field (24 ≤ len ≤ 4 MiB) |
//! | 4        | 4    | magic    | `POLW`                               |
//! | 8        | 2    | version  | [`PROTO_VERSION`]                    |
//! | 10       | 1    | op       | [`Op`]                               |
//! | 11       | 1    | status   | 0 on requests; [`STATUS_OK`]/error on responses |
//! | 12       | 8    | req_id   | echoed verbatim in the response      |
//! | 20       | n    | payload  | op-specific                          |
//! | 20 + n   | 8    | checksum | FNV-1a64 over magic..payload         |
//!
//! Every cap is enforced *before* the corresponding allocation: a
//! hostile length prefix beyond [`MAX_FRAME`] is rejected after reading
//! four bytes, and every count inside a payload (batch size, features
//! per instance, name length) is validated against both its cap and the
//! bytes actually present — the decoder never allocates proportionally
//! to an attacker-chosen number, only to bytes actually received (and
//! those are capped at one frame). This mirrors the `.polz` codec
//! discipline in [`crate::serve::checkpoint`] and reuses the same
//! [`crate::hashing::fnv1a64`] checksum — which since the SIMD pass
//! runs the dispatched 8-bytes-per-load scan from [`crate::simd`],
//! so whole-frame checksumming no longer walks the body a byte at a
//! time (bit-identical: same serial FNV recurrence).

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::hashing::fnv1a64;
use crate::linalg::SparseFeat;

/// Frame magic: `POLW` ("parallel online learning, wire").
pub const MAGIC: [u8; 4] = *b"POLW";

/// Protocol version; peers speaking another version are rejected.
pub const PROTO_VERSION: u16 = 1;

/// Body bytes of an empty-payload frame: 16-byte header + 8 checksum.
pub const MIN_FRAME: u32 = 24;

/// Hard cap on the length prefix (body bytes): one frame can never make
/// the peer allocate more than this.
pub const MAX_FRAME: u32 = 1 << 22;

/// Instances per `PredictBatch` frame.
pub const MAX_BATCH: u32 = 4_096;

/// Sparse features per instance.
pub const MAX_FEATURES: u32 = 1 << 16;

/// Model-name bytes (names are length-prefixed with one byte).
pub const MAX_NAME: usize = 255;

/// Ping echo-payload bytes.
pub const MAX_PING: usize = 4_096;

/// Response status: success.
pub const STATUS_OK: u8 = 0;
/// Response status: payload did not parse for its op.
pub const STATUS_BAD_FRAME: u8 = 1;
/// Response status: op byte not in [`Op`].
pub const STATUS_UNKNOWN_OP: u8 = 2;
/// Response status: no model registered under the requested name.
pub const STATUS_UNKNOWN_MODEL: u8 = 3;
/// Response status: a payload count exceeded its cap.
pub const STATUS_TOO_LARGE: u8 = 4;
/// Response status: server is draining; retry against another replica.
pub const STATUS_SHUTTING_DOWN: u8 = 5;
/// Response status: op understood but not permitted (e.g. `Shutdown`
/// on a server that disabled remote shutdown).
pub const STATUS_FORBIDDEN: u8 = 6;

/// Human-readable name for a response status code.
pub fn status_name(status: u8) -> &'static str {
    match status {
        STATUS_OK => "ok",
        STATUS_BAD_FRAME => "bad frame",
        STATUS_UNKNOWN_OP => "unknown op",
        STATUS_UNKNOWN_MODEL => "unknown model",
        STATUS_TOO_LARGE => "over cap",
        STATUS_SHUTTING_DOWN => "shutting down",
        STATUS_FORBIDDEN => "forbidden",
        _ => "unknown status",
    }
}

/// Operation codes. Requests carry one of these; the response echoes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Score one instance: `name | nnz:u32 | nnz × (idx:u32, val:f32)`.
    Predict = 1,
    /// Score many instances in one frame:
    /// `name | count:u32 | count × instance`.
    PredictBatch = 2,
    /// Admin: wire-level + per-model serving stats (empty payload).
    Stats = 3,
    /// Admin: registered models with dim/version/params (empty payload).
    ListModels = 4,
    /// Liveness probe; the payload (≤ [`MAX_PING`] bytes) is echoed.
    Ping = 5,
    /// Admin: acknowledge, then gracefully drain the server.
    Shutdown = 6,
    /// Admin: the full metrics registry in the versioned text
    /// exposition format (request payload empty; response payload is
    /// the UTF-8 text, already bounded by the frame cap).
    MetricsDump = 7,
    /// Admin: the server's bounded ring of periodic registry
    /// snapshots (request payload empty; response payload is
    /// `nsnaps:u32 | nsnaps × snapshot` — see [`put_history`]), so
    /// rates and trends are a server-side fact.
    MetricsHistory = 8,
}

impl Op {
    /// Decode an opcode byte; `None` for unknown ops.
    pub fn from_u8(op: u8) -> Option<Op> {
        match op {
            1 => Some(Op::Predict),
            2 => Some(Op::PredictBatch),
            3 => Some(Op::Stats),
            4 => Some(Op::ListModels),
            5 => Some(Op::Ping),
            6 => Some(Op::Shutdown),
            7 => Some(Op::MetricsDump),
            8 => Some(Op::MetricsHistory),
            _ => None,
        }
    }
}

/// Why a frame failed to decode. Framing-level corruption (bad length,
/// magic, version, checksum, truncation) means the byte stream can no
/// longer be trusted and the connection should close; payload-level
/// errors are answerable with a typed error frame.
#[derive(Debug)]
pub enum FrameError {
    /// Transport failure while reading/writing.
    Io(io::Error),
    /// Declared body length outside `[MIN_FRAME, MAX_FRAME]` — rejected
    /// before any allocation.
    BadLength { len: u32 },
    /// First four body bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Peer speaks a different protocol version.
    BadVersion(u16),
    /// FNV-1a64 over the body did not match the trailing checksum.
    ChecksumMismatch,
    /// Stream ended (or the peer stalled) mid-frame.
    Truncated,
    /// The payload did not parse for its op.
    BadPayload(&'static str),
    /// A count in the payload exceeded its cap.
    OverCap(&'static str),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "wire i/o: {e}"),
            FrameError::BadLength { len } => write!(
                f,
                "bad frame length {len} (valid: {MIN_FRAME}..={MAX_FRAME})"
            ),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?} (want {MAGIC:02x?})")
            }
            FrameError::BadVersion(v) => {
                write!(f, "protocol version {v} (this peer speaks {PROTO_VERSION})")
            }
            FrameError::ChecksumMismatch => write!(f, "frame checksum mismatch"),
            FrameError::Truncated => write!(f, "truncated frame"),
            FrameError::BadPayload(what) => write!(f, "bad payload: {what}"),
            FrameError::OverCap(what) => write!(f, "over cap: {what}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

// ---- little-endian scalar helpers -----------------------------------

pub(crate) fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed (one byte) string; caller enforces [`MAX_NAME`]
/// (the client bounds request names up front, and the admin encoders
/// filter out unrepresentable registry names), so the `as u8` below
/// can never wrap into a desynced frame.
pub(crate) fn put_name(out: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= MAX_NAME);
    // pol-lint: allow(L006, "MAX_NAME = 255; encoders filter longer names")
    out.push(name.len() as u8);
    out.extend_from_slice(name.as_bytes());
}

/// Bounds-checked payload cursor: every `take_*` validates against the
/// bytes actually present before touching them, so a lying count can
/// never read past the frame or trigger an oversized allocation.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    /// A cursor over `b`.
    pub fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b }
    }

    /// Bytes left.
    pub fn remaining(&self) -> usize {
        self.b.len()
    }

    /// Take the next `n` bytes, erroring on underrun.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if n > self.b.len() {
            return Err(FrameError::Truncated);
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    /// Read one byte.
    pub fn take_u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn take_u16(&mut self) -> Result<u16, FrameError> {
        Ok(crate::bytes::le_u16(self.take(2)?))
    }

    /// Read a little-endian `u32`.
    pub fn take_u32(&mut self) -> Result<u32, FrameError> {
        Ok(crate::bytes::le_u32(self.take(4)?))
    }

    /// Read a little-endian `u64`.
    pub fn take_u64(&mut self) -> Result<u64, FrameError> {
        Ok(crate::bytes::le_u64(self.take(8)?))
    }

    /// Read a little-endian `f32`.
    pub fn take_f32(&mut self) -> Result<f32, FrameError> {
        Ok(crate::bytes::le_f32(self.take(4)?))
    }

    /// Read a little-endian `f64`.
    pub fn take_f64(&mut self) -> Result<f64, FrameError> {
        Ok(crate::bytes::le_f64(self.take(8)?))
    }

    /// Read a length-prefixed UTF-8 name.
    pub fn take_name(&mut self) -> Result<&'a str, FrameError> {
        let len = self.take_u8()? as usize;
        std::str::from_utf8(self.take(len)?)
            .map_err(|_| FrameError::BadPayload("model name is not UTF-8"))
    }

    /// Error unless the payload was fully consumed.
    pub fn finish(self) -> Result<(), FrameError> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(FrameError::BadPayload("trailing bytes after payload"))
        }
    }
}

// ---- frame encode ---------------------------------------------------

/// Reusable frame builder: `start`, append payload bytes through
/// [`FrameWriter::payload`], then [`FrameWriter::finish_to`] — which
/// seals the checksum and writes `len | body` in one buffered write.
/// Steady state allocates nothing (the body buffer is recycled).
pub struct FrameWriter {
    body: Vec<u8>,
}

impl FrameWriter {
    /// An empty writer.
    pub fn new() -> FrameWriter {
        FrameWriter { body: Vec::with_capacity(256) }
    }

    /// Begin a frame; any previous contents are discarded.
    pub fn start(&mut self, op: u8, status: u8, req_id: u64) {
        self.body.clear();
        self.body.extend_from_slice(&MAGIC);
        put_u16(&mut self.body, PROTO_VERSION);
        self.body.push(op);
        self.body.push(status);
        put_u64(&mut self.body, req_id);
    }

    /// The payload under construction (append with the `put_*` helpers).
    pub fn payload(&mut self) -> &mut Vec<u8> {
        &mut self.body
    }

    /// Seal the checksum and write the frame; returns bytes written.
    /// Fails (before writing anything) if the payload grew past
    /// [`MAX_FRAME`] — the writer enforces the reader's cap, so a frame
    /// that sends is always receivable.
    pub fn finish_to(&mut self, out: &mut impl Write) -> io::Result<usize> {
        let sum = fnv1a64(&self.body);
        put_u64(&mut self.body, sum);
        let len = self.body.len();
        let len32 = u32::try_from(len)
            .ok()
            .filter(|&n| n <= MAX_FRAME)
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("frame body {len} bytes exceeds cap {MAX_FRAME}"),
                )
            })?;
        out.write_all(&len32.to_le_bytes())?;
        out.write_all(&self.body)?;
        Ok(4 + self.body.len())
    }
}

impl Default for FrameWriter {
    fn default() -> Self {
        FrameWriter::new()
    }
}

// ---- frame decode ---------------------------------------------------

/// One decoded frame, borrowing the connection's reusable buffer.
#[derive(Debug)]
pub struct Frame<'a> {
    /// Raw op byte (map through [`Op::from_u8`]; unknown ops get a
    /// typed error response rather than a decode failure).
    pub op: u8,
    /// Response status byte (0 = ok).
    pub status: u8,
    /// Request id echoed back to the client.
    pub req_id: u64,
    /// Opcode-specific payload bytes.
    pub payload: &'a [u8],
    /// Wire size of this frame including the length prefix.
    pub wire_bytes: usize,
}

/// Reusable receive buffer; its capacity is bounded by [`MAX_FRAME`].
pub struct FrameBuf {
    body: Vec<u8>,
}

impl FrameBuf {
    /// An empty reusable receive buffer.
    pub fn new() -> FrameBuf {
        FrameBuf { body: Vec::with_capacity(256) }
    }
}

impl Default for FrameBuf {
    fn default() -> Self {
        FrameBuf::new()
    }
}

/// Read exactly `buf.len()` bytes. `Ok(false)` means the stream ended
/// cleanly before the first byte (only meaningful for the length
/// prefix); a timeout checks `stop` and `deadline` and either keeps
/// waiting or bails out — with `Ok(false)` at a frame boundary (drain
/// or idle expiry is a clean close), [`FrameError::Truncated`]
/// mid-read (a peer that stalls inside a frame is indistinguishable
/// from a truncating one).
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    stop: Option<&AtomicBool>,
    deadline: Option<std::time::Instant>,
    at_boundary: bool,
) -> Result<bool, FrameError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return if got == 0 && at_boundary {
                    Ok(false) // clean close between frames
                } else {
                    Err(FrameError::Truncated)
                };
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                ) && stop.is_some() =>
            {
                let drain =
                    stop.is_some_and(|s| s.load(Ordering::Acquire));
                let expired = deadline
                    .is_some_and(|d| std::time::Instant::now() >= d);
                if drain || expired {
                    return if got == 0 && at_boundary {
                        Ok(false) // draining/idle: close between frames
                    } else {
                        Err(FrameError::Truncated)
                    };
                }
                // timeout with no drain and no expiry: keep waiting
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(true)
}

/// Read and validate one frame into `buf`. `Ok(None)` is a clean close
/// (EOF between frames, `stop` set while idle, or `idle_deadline`
/// passed while idle — the slow-loris guard: a peer that holds a
/// connection without sending a frame is disconnected at the
/// deadline). Length, magic, version, and checksum are all verified
/// here; the length cap is checked *before* the body buffer grows, so
/// a hostile length prefix can never force an allocation.
pub fn read_frame<'a>(
    r: &mut impl Read,
    buf: &'a mut FrameBuf,
    stop: Option<&AtomicBool>,
    idle_deadline: Option<std::time::Instant>,
) -> Result<Option<Frame<'a>>, FrameError> {
    let mut len4 = [0u8; 4];
    if !read_full(r, &mut len4, stop, idle_deadline, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4);
    if !(MIN_FRAME..=MAX_FRAME).contains(&len) {
        return Err(FrameError::BadLength { len });
    }
    buf.body.resize(len as usize, 0);
    if !read_full(r, &mut buf.body, stop, idle_deadline, false)? {
        return Err(FrameError::Truncated);
    }
    let body = &buf.body[..];
    let (content, sum_bytes) = body.split_at(body.len() - 8);
    let sum = crate::bytes::le_u64(sum_bytes);
    if fnv1a64(content) != sum {
        return Err(FrameError::ChecksumMismatch);
    }
    if content[0..4] != MAGIC {
        let mut magic = [0u8; 4];
        magic.copy_from_slice(&content[0..4]);
        return Err(FrameError::BadMagic(magic));
    }
    let version = crate::bytes::le_u16(&content[4..6]);
    if version != PROTO_VERSION {
        return Err(FrameError::BadVersion(version));
    }
    Ok(Some(Frame {
        op: content[6],
        status: content[7],
        req_id: crate::bytes::le_u64(&content[8..16]),
        payload: &content[16..],
        wire_bytes: 4 + len as usize,
    }))
}

/// Decode one frame from the *front* of an accumulation buffer — the
/// nonblocking twin of [`read_frame`], for callers that gather bytes
/// with readiness-driven partial reads instead of blocking on a
/// stream. `Ok(None)` means "incomplete: keep reading"; `Ok(Some((f,
/// consumed)))` yields the frame plus the byte count to drop from the
/// buffer's front. The length prefix is validated as soon as its four
/// bytes are present — a hostile claim past [`MAX_FRAME`] is rejected
/// *before* the caller buffers anything toward it, so the
/// accumulation buffer only ever grows by bytes actually received
/// (and a complete valid frame always fits in `MAX_FRAME + 4`).
/// Framing-level validation (magic, version, checksum) is identical
/// to [`read_frame`], so the two decoders accept exactly the same
/// byte streams.
pub fn decode_frame_from(
    buf: &[u8],
) -> Result<Option<(Frame<'_>, usize)>, FrameError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = crate::bytes::le_u32(&buf[..4]);
    if !(MIN_FRAME..=MAX_FRAME).contains(&len) {
        return Err(FrameError::BadLength { len });
    }
    let total = 4 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let body = &buf[4..total];
    let (content, sum_bytes) = body.split_at(body.len() - 8);
    let sum = crate::bytes::le_u64(sum_bytes);
    if fnv1a64(content) != sum {
        return Err(FrameError::ChecksumMismatch);
    }
    if content[0..4] != MAGIC {
        let mut magic = [0u8; 4];
        magic.copy_from_slice(&content[0..4]);
        return Err(FrameError::BadMagic(magic));
    }
    let version = crate::bytes::le_u16(&content[4..6]);
    if version != PROTO_VERSION {
        return Err(FrameError::BadVersion(version));
    }
    Ok(Some((
        Frame {
            op: content[6],
            status: content[7],
            req_id: crate::bytes::le_u64(&content[8..16]),
            payload: &content[16..],
            wire_bytes: total,
        },
        total,
    )))
}

// ---- predict payloads -----------------------------------------------

/// Append one instance (`nnz | nnz × (idx, val)`) to a payload.
/// Errors if the instance exceeds [`MAX_FEATURES`].
pub fn put_instance(out: &mut Vec<u8>, x: &[SparseFeat]) -> io::Result<()> {
    let nnz = u32::try_from(x.len())
        .ok()
        .filter(|&n| n <= MAX_FEATURES)
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "instance has {} features (wire cap {MAX_FEATURES})",
                    x.len()
                ),
            )
        })?;
    put_u32(out, nnz);
    for &(i, v) in x {
        put_u32(out, i);
        put_f32(out, v);
    }
    Ok(())
}

/// Features a recycled instance buffer keeps capacity for between
/// frames. Typical instances sit far below this (the synthetic
/// workloads run ~75–150 nnz), so the steady-state decode path still
/// allocates nothing — but a burst of [`MAX_FEATURES`]-sized instances
/// can no longer pin `MAX_BATCH × MAX_FEATURES × 8` bytes (≈ 2 GiB)
/// of scratch for a connection's lifetime: retained capacity is
/// bounded at `MAX_BATCH × RETAINED_FEATURES × 8` ≈ 8 MiB.
const RETAINED_FEATURES: usize = 256;

/// Decoded-request scratch: instance buffers recycled across frames
/// (capacity retention bounded by [`RETAINED_FEATURES`] per slot), so
/// the steady-state decode path allocates nothing.
#[derive(Default)]
pub struct BatchScratch {
    instances: Vec<Vec<SparseFeat>>,
    used: usize,
}

impl BatchScratch {
    /// The instances decoded by the last
    /// [`decode_predict_request`] call.
    pub fn batch(&self) -> &[Vec<SparseFeat>] {
        &self.instances[..self.used]
    }

    /// Give back capacity left by previous frames' oversized
    /// instances — the hostile-peer memory-retention bound (see
    /// [`RETAINED_FEATURES`]). Called at the start of every decode, so
    /// only the *current* frame's actual content can ever exceed the
    /// retained bound, and only until the next frame arrives.
    fn reclaim(&mut self) {
        for slot in &mut self.instances {
            if slot.capacity() > RETAINED_FEATURES {
                slot.clear();
                slot.shrink_to(RETAINED_FEATURES);
            }
        }
    }

    fn next_mut(&mut self) -> &mut Vec<SparseFeat> {
        if self.used == self.instances.len() {
            self.instances.push(Vec::new());
        }
        self.used += 1;
        let slot = &mut self.instances[self.used - 1];
        slot.clear();
        slot
    }
}

fn take_instance_into(
    cur: &mut Cur<'_>,
    out: &mut Vec<SparseFeat>,
) -> Result<(), FrameError> {
    let nnz = cur.take_u32()?;
    if nnz > MAX_FEATURES {
        return Err(FrameError::OverCap("features per instance"));
    }
    // 8 bytes per feature must actually be present before reserving
    if (nnz as usize) * 8 > cur.remaining() {
        return Err(FrameError::Truncated);
    }
    out.reserve(nnz as usize);
    for _ in 0..nnz {
        let i = cur.take_u32()?;
        let v = cur.take_f32()?;
        out.push((i, v));
    }
    Ok(())
}

/// Decode a [`Op::Predict`] / [`Op::PredictBatch`] payload into the
/// recycled scratch; returns the target model name (borrowed from the
/// frame buffer).
pub fn decode_predict_request<'a>(
    op: Op,
    payload: &'a [u8],
    scratch: &mut BatchScratch,
) -> Result<&'a str, FrameError> {
    scratch.used = 0;
    scratch.reclaim();
    let mut cur = Cur::new(payload);
    let name = cur.take_name()?;
    let count = match op {
        Op::Predict => 1,
        Op::PredictBatch => {
            let count = cur.take_u32()?;
            if count > MAX_BATCH {
                return Err(FrameError::OverCap("batch size"));
            }
            // an empty batch is well-formed (responds with zero preds);
            // each instance needs at least its nnz word
            if (count as usize) * 4 > cur.remaining() {
                return Err(FrameError::Truncated);
            }
            count
        }
        _ => return Err(FrameError::BadPayload("not a predict op")),
    };
    for _ in 0..count {
        take_instance_into(&mut cur, scratch.next_mut())?;
    }
    cur.finish()?;
    Ok(name)
}

/// Encode a predict response payload:
/// `count:u32 | count × pred:f64 | snapshot_version:u64 | staleness:u64`.
pub fn put_predict_response(
    out: &mut Vec<u8>,
    preds: &[f64],
    snapshot_version: u64,
    staleness: u64,
) {
    // pol-lint: allow(L006, "preds mirrors a decoded batch, len <= MAX_BATCH")
    put_u32(out, preds.len() as u32);
    for &p in preds {
        put_f64(out, p);
    }
    put_u64(out, snapshot_version);
    put_u64(out, staleness);
}

/// Decode a predict response into `preds` (cleared first); returns
/// `(snapshot_version, staleness)`.
pub fn decode_predict_response(
    payload: &[u8],
    preds: &mut Vec<f64>,
) -> Result<(u64, u64), FrameError> {
    preds.clear();
    let mut cur = Cur::new(payload);
    let count = cur.take_u32()?;
    if count > MAX_BATCH {
        return Err(FrameError::OverCap("batch size"));
    }
    if (count as usize) * 8 > cur.remaining() {
        return Err(FrameError::Truncated);
    }
    preds.reserve(count as usize);
    for _ in 0..count {
        preds.push(cur.take_f64()?);
    }
    let version = cur.take_u64()?;
    let staleness = cur.take_u64()?;
    cur.finish()?;
    Ok((version, staleness))
}

// ---- admin payloads -------------------------------------------------

/// Per-model serving stats as reported over the wire (quantiles are
/// pre-derived from the server's [`crate::metrics::LatencyHistogram`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelStatsReport {
    /// Model name.
    pub name: String,
    /// Requests served.
    pub requests: u64,
    /// Predictions returned.
    pub predictions: u64,
    /// Median request latency in nanoseconds.
    pub p50_ns: u64,
    /// 99th-percentile request latency in nanoseconds.
    pub p99_ns: u64,
    /// Largest request latency in nanoseconds.
    pub max_ns: u64,
    /// Largest snapshot staleness observed.
    pub max_staleness: u64,
}

/// Wire-level stats as reported by the [`Op::Stats`] admin op.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsReport {
    /// Bytes read from clients.
    pub bytes_in: u64,
    /// Bytes written to clients.
    pub bytes_out: u64,
    /// Frames decoded.
    pub frames_in: u64,
    /// Frames sent.
    pub frames_out: u64,
    /// Frames rejected by the decoder.
    pub decode_errors: u64,
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Currently open connections.
    pub active_connections: u64,
    /// Server uptime in microseconds.
    pub uptime_us: u64,
    /// Registry generation at report time (bumps on every insert,
    /// replace, or remove) — a scraper can detect hot-swaps from the
    /// Stats payload alone.
    pub registry_version: u64,
    /// Number of models the registry held at report time.
    pub registry_models: u64,
    /// Per-model breakdowns.
    pub models: Vec<ModelStatsReport>,
}

impl StatsReport {
    /// Build a report from an in-process [`ServeStats`] — the
    /// same shape the wire server exposes, so both front-ends print
    /// through one formatting path ([`Self::render_text`]). The wire
    /// counters stay zero: an in-process server has no wire.
    ///
    /// [`ServeStats`]: crate::serve::server::ServeStats
    pub fn from_serve(s: &crate::serve::server::ServeStats) -> StatsReport {
        StatsReport {
            uptime_us: s.elapsed.as_micros() as u64,
            models: s
                .per_model
                .iter()
                .map(|(name, m)| ModelStatsReport {
                    name: name.clone(),
                    requests: m.requests,
                    predictions: m.predictions,
                    p50_ns: m.latency.quantile_ns(0.5),
                    p99_ns: m.latency.quantile_ns(0.99),
                    max_ns: m.latency.max_ns(),
                    max_staleness: m.max_staleness,
                })
                .collect(),
            ..StatsReport::default()
        }
    }

    /// The per-model lines (`model=NAME requests=… …`), one per model,
    /// newline-terminated — the single formatting path shared by
    /// `pol serve-stats`, `pol serve --listen`'s exit report, and the
    /// in-process `pol serve` display.
    pub fn render_models_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for m in &self.models {
            let _ = writeln!(
                out,
                "model={} requests={} predictions={} p50_us={:.1} \
                 p99_us={:.1} max_us={:.1} max_staleness={}",
                m.name,
                m.requests,
                m.predictions,
                m.p50_ns as f64 / 1e3,
                m.p99_ns as f64 / 1e3,
                m.max_ns as f64 / 1e3,
                m.max_staleness
            );
        }
        out
    }

    /// The full text report: one wire-level header line, then
    /// [`Self::render_models_text`].
    pub fn render_text(&self) -> String {
        format!(
            "uptime_s={:.1} connections={} active={} frames_in={} \
             frames_out={} bytes_in={} bytes_out={} decode_errors={}\n{}",
            self.uptime_us as f64 / 1e6,
            self.connections,
            self.active_connections,
            self.frames_in,
            self.frames_out,
            self.bytes_in,
            self.bytes_out,
            self.decode_errors,
            self.render_models_text()
        )
    }
}

/// A name the one-byte length prefix can carry. Longer registry names
/// cannot be addressed by any request frame either (request names are
/// capped the same way), so the admin encoders omit such entries
/// instead of emitting a desynced frame.
fn wire_named<T>(items: &[T], name: impl Fn(&T) -> &str) -> Vec<&T> {
    items.iter().filter(|m| name(m).len() <= MAX_NAME).collect()
}

/// Encode a stats report payload.
pub fn put_stats(out: &mut Vec<u8>, s: &StatsReport) {
    put_u64(out, s.bytes_in);
    put_u64(out, s.bytes_out);
    put_u64(out, s.frames_in);
    put_u64(out, s.frames_out);
    put_u64(out, s.decode_errors);
    put_u64(out, s.connections);
    put_u64(out, s.active_connections);
    put_u64(out, s.uptime_us);
    put_u64(out, s.registry_version);
    put_u64(out, s.registry_models);
    let models = wire_named(&s.models, |m| &m.name);
    // pol-lint: allow(L006, "registry model count is far below u32::MAX")
    put_u32(out, models.len() as u32);
    for m in models {
        put_name(out, &m.name);
        put_u64(out, m.requests);
        put_u64(out, m.predictions);
        put_u64(out, m.p50_ns);
        put_u64(out, m.p99_ns);
        put_u64(out, m.max_ns);
        put_u64(out, m.max_staleness);
    }
}

/// Decode a stats report payload.
pub fn decode_stats(payload: &[u8]) -> Result<StatsReport, FrameError> {
    let mut cur = Cur::new(payload);
    let mut s = StatsReport {
        bytes_in: cur.take_u64()?,
        bytes_out: cur.take_u64()?,
        frames_in: cur.take_u64()?,
        frames_out: cur.take_u64()?,
        decode_errors: cur.take_u64()?,
        connections: cur.take_u64()?,
        active_connections: cur.take_u64()?,
        uptime_us: cur.take_u64()?,
        registry_version: cur.take_u64()?,
        registry_models: cur.take_u64()?,
        models: Vec::new(),
    };
    let count = cur.take_u32()?;
    // name prefix + six u64 counters per entry must be present
    if (count as usize) * (1 + 48) > cur.remaining() {
        return Err(FrameError::Truncated);
    }
    for _ in 0..count {
        let name = cur.take_name()?.to_string();
        s.models.push(ModelStatsReport {
            name,
            requests: cur.take_u64()?,
            predictions: cur.take_u64()?,
            p50_ns: cur.take_u64()?,
            p99_ns: cur.take_u64()?,
            max_ns: cur.take_u64()?,
            max_staleness: cur.take_u64()?,
        });
    }
    cur.finish()?;
    Ok(s)
}

/// One registry entry as reported by the [`Op::ListModels`] admin op.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelEntry {
    /// Model name.
    pub name: String,
    /// Feature dimension.
    pub dim: u64,
    /// Parameter count.
    pub params: u64,
    /// Version of the served snapshot.
    pub snapshot_version: u64,
    /// Instances trained into the snapshot.
    pub trained_instances: u64,
}

/// Encode a model-list payload.
pub fn put_models(out: &mut Vec<u8>, models: &[ModelEntry]) {
    let models = wire_named(models, |m| &m.name);
    // pol-lint: allow(L006, "registry model count is far below u32::MAX")
    put_u32(out, models.len() as u32);
    for m in models {
        put_name(out, &m.name);
        put_u64(out, m.dim);
        put_u64(out, m.params);
        put_u64(out, m.snapshot_version);
        put_u64(out, m.trained_instances);
    }
}

// ---- metrics-history payload ----------------------------------------

/// Snapshots one [`Op::MetricsHistory`] response may carry.
pub const MAX_HISTORY_SNAPSHOTS: u32 = 256;
/// Series entries per history snapshot.
pub const MAX_HISTORY_SERIES: u32 = 4_096;
/// Bytes in one series name (label block included).
pub const MAX_SERIES_NAME: u32 = 512;

/// Fixed per-snapshot overhead: tick + uptime + series count.
const HIST_SNAP_HEAD: usize = 8 + 8 + 4;
/// Fixed per-series overhead: name length + value.
const HIST_ENTRY_HEAD: usize = 2 + 8;

/// A series name as it rides the history payload: truncated to
/// [`MAX_SERIES_NAME`] on a char boundary.
fn history_name(name: &str) -> &str {
    if name.len() <= MAX_SERIES_NAME as usize {
        return name;
    }
    let mut cut = MAX_SERIES_NAME as usize;
    while !name.is_char_boundary(cut) {
        cut -= 1;
    }
    &name[..cut]
}

fn encoded_snapshot_len(s: &crate::obs::SeriesSnapshot) -> usize {
    let take = s.series.len().min(MAX_HISTORY_SERIES as usize);
    HIST_SNAP_HEAD
        + s.series
            .iter()
            .take(take)
            .map(|(n, _)| HIST_ENTRY_HEAD + history_name(n).len())
            .sum::<usize>()
}

/// Encode a metrics-history payload:
/// `nsnaps:u32 | per snapshot (u64 tick | u64 uptime_ms | u32 nseries
/// | per series (u16 name_len | name | u64 value))`, oldest first.
/// Keeps the newest snapshots that fit both [`MAX_HISTORY_SNAPSHOTS`]
/// and the frame budget (older history is droppable; the newest
/// window is what rates are computed from), and truncates series
/// lists and names to their caps — a payload that encodes always
/// decodes and always frames.
pub fn put_history(
    out: &mut Vec<u8>,
    snaps: &[crate::obs::SeriesSnapshot],
) {
    // leave headroom for the frame header and checksum already in /
    // appended around this payload
    let budget = (MAX_FRAME as usize).saturating_sub(out.len() + 64);
    let mut first = snaps.len();
    let mut used = 4usize;
    while first > 0 {
        if snaps.len() - first == MAX_HISTORY_SNAPSHOTS as usize {
            break;
        }
        let need = encoded_snapshot_len(&snaps[first - 1]);
        if used + need > budget {
            break;
        }
        used += need;
        first -= 1;
    }
    let kept = &snaps[first..];
    // pol-lint: allow(L006, "len capped to MAX_HISTORY_SNAPSHOTS above")
    put_u32(out, kept.len() as u32);
    for s in kept {
        put_u64(out, s.tick);
        put_u64(out, s.uptime_ms);
        let take = s.series.len().min(MAX_HISTORY_SERIES as usize);
        // pol-lint: allow(L006, "len capped to MAX_HISTORY_SERIES above")
        put_u32(out, take as u32);
        for (n, v) in s.series.iter().take(take) {
            let name = history_name(n);
            // pol-lint: allow(L006, "name truncated to MAX_SERIES_NAME above")
            put_u16(out, name.len() as u16);
            out.extend_from_slice(name.as_bytes());
            put_u64(out, *v);
        }
    }
}

/// Decode a metrics-history payload. Every count is validated against
/// its cap and the bytes actually present before the corresponding
/// allocation — the cap-before-allocate discipline of every other op.
pub fn decode_history(
    payload: &[u8],
) -> Result<Vec<crate::obs::SeriesSnapshot>, FrameError> {
    let mut cur = Cur::new(payload);
    let nsnaps = cur.take_u32()?;
    if nsnaps > MAX_HISTORY_SNAPSHOTS {
        return Err(FrameError::OverCap("history snapshot count"));
    }
    if (nsnaps as usize) * HIST_SNAP_HEAD > cur.remaining() {
        return Err(FrameError::Truncated);
    }
    let mut snaps = Vec::with_capacity(nsnaps as usize);
    for _ in 0..nsnaps {
        let tick = cur.take_u64()?;
        let uptime_ms = cur.take_u64()?;
        let nseries = cur.take_u32()?;
        if nseries > MAX_HISTORY_SERIES {
            return Err(FrameError::OverCap("history series count"));
        }
        if (nseries as usize) * HIST_ENTRY_HEAD > cur.remaining() {
            return Err(FrameError::Truncated);
        }
        let mut series = Vec::with_capacity(nseries as usize);
        for _ in 0..nseries {
            let nlen = cur.take_u16()?;
            if u32::from(nlen) > MAX_SERIES_NAME {
                return Err(FrameError::OverCap("history series name"));
            }
            let name = std::str::from_utf8(cur.take(nlen as usize)?)
                .map_err(|_| {
                    FrameError::BadPayload("series name is not UTF-8")
                })?
                .to_string();
            let value = cur.take_u64()?;
            series.push((name, value));
        }
        snaps.push(crate::obs::SeriesSnapshot { tick, uptime_ms, series });
    }
    cur.finish()?;
    Ok(snaps)
}

/// Decode a model-list payload.
pub fn decode_models(payload: &[u8]) -> Result<Vec<ModelEntry>, FrameError> {
    let mut cur = Cur::new(payload);
    let count = cur.take_u32()?;
    if (count as usize) * (1 + 32) > cur.remaining() {
        return Err(FrameError::Truncated);
    }
    let mut models = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let name = cur.take_name()?.to_string();
        models.push(ModelEntry {
            name,
            dim: cur.take_u64()?,
            params: cur.take_u64()?,
            snapshot_version: cur.take_u64()?,
            trained_instances: cur.take_u64()?,
        });
    }
    cur.finish()?;
    Ok(models)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(op: u8, status: u8, req_id: u64, payload: &[u8]) -> Vec<u8> {
        let mut w = FrameWriter::new();
        w.start(op, status, req_id);
        w.payload().extend_from_slice(payload);
        let mut out = Vec::new();
        let n = w.finish_to(&mut out).unwrap();
        assert_eq!(n, out.len());
        out
    }

    #[test]
    fn frame_round_trips() {
        let bytes = round_trip(Op::Ping as u8, STATUS_OK, 42, b"hello");
        let mut buf = FrameBuf::new();
        let f = read_frame(&mut bytes.as_slice(), &mut buf, None, None)
            .unwrap()
            .unwrap();
        assert_eq!(f.op, Op::Ping as u8);
        assert_eq!(f.status, STATUS_OK);
        assert_eq!(f.req_id, 42);
        assert_eq!(f.payload, b"hello");
        assert_eq!(f.wire_bytes, bytes.len());
    }

    #[test]
    fn eof_at_boundary_is_clean_close() {
        let mut buf = FrameBuf::new();
        let got = read_frame(&mut (&[][..]), &mut buf, None, None).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_hang() {
        let bytes = round_trip(Op::Ping as u8, STATUS_OK, 1, b"abc");
        for cut in 1..bytes.len() {
            let mut buf = FrameBuf::new();
            let err = read_frame(&mut &bytes[..cut], &mut buf, None, None);
            assert!(
                matches!(err, Err(FrameError::Truncated)),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        // a 4 GiB claim must fail after four bytes, not allocate
        let mut bytes = (u32::MAX).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 64]);
        let mut buf = FrameBuf::new();
        let err = read_frame(&mut bytes.as_slice(), &mut buf, None, None);
        assert!(matches!(
            err,
            Err(FrameError::BadLength { len: u32::MAX })
        ));
        // the receive buffer never grew toward the claimed 4 GiB
        assert!(buf.body.capacity() <= 256, "{}", buf.body.capacity());
        // under-length frames are rejected the same way
        let mut tiny = 8u32.to_le_bytes().to_vec();
        tiny.extend_from_slice(&[0u8; 8]);
        let mut buf = FrameBuf::new();
        assert!(matches!(
            read_frame(&mut tiny.as_slice(), &mut buf, None, None),
            Err(FrameError::BadLength { len: 8 })
        ));
    }

    #[test]
    fn bad_magic_version_checksum_rejected() {
        let good = round_trip(Op::Stats as u8, STATUS_OK, 7, b"");
        // flip a payload-region byte: checksum catches it
        let mut corrupt = good.clone();
        let last = corrupt.len() - 9; // inside req_id
        corrupt[last] ^= 0xFF;
        let mut buf = FrameBuf::new();
        assert!(matches!(
            read_frame(&mut corrupt.as_slice(), &mut buf, None, None),
            Err(FrameError::ChecksumMismatch)
        ));
        // checksum valid but magic wrong
        let mut w = FrameWriter::new();
        w.start(Op::Stats as u8, STATUS_OK, 7);
        w.body[0] = b'X';
        let mut bytes = Vec::new();
        w.finish_to(&mut bytes).unwrap();
        let mut buf = FrameBuf::new();
        assert!(matches!(
            read_frame(&mut bytes.as_slice(), &mut buf, None, None),
            Err(FrameError::BadMagic(_))
        ));
        // checksum valid but version unknown
        let mut w = FrameWriter::new();
        w.start(Op::Stats as u8, STATUS_OK, 7);
        w.body[4] = 0xEE;
        w.body[5] = 0xEE;
        let mut bytes = Vec::new();
        w.finish_to(&mut bytes).unwrap();
        let mut buf = FrameBuf::new();
        assert!(matches!(
            read_frame(&mut bytes.as_slice(), &mut buf, None, None),
            Err(FrameError::BadVersion(0xEEEE))
        ));
    }

    #[test]
    fn predict_payload_round_trips() {
        let x1: Vec<SparseFeat> = vec![(0, 1.5), (7, -2.0)];
        let x2: Vec<SparseFeat> = vec![(3, 0.25)];
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_u32(&mut payload, 2);
        put_instance(&mut payload, &x1).unwrap();
        put_instance(&mut payload, &x2).unwrap();
        let mut scratch = BatchScratch::default();
        let name =
            decode_predict_request(Op::PredictBatch, &payload, &mut scratch)
                .unwrap();
        assert_eq!(name, "m");
        assert_eq!(scratch.batch(), &[x1.clone(), x2]);
        // single-predict framing: no count word
        let mut payload = Vec::new();
        put_name(&mut payload, "solo");
        put_instance(&mut payload, &x1).unwrap();
        let name = decode_predict_request(Op::Predict, &payload, &mut scratch)
            .unwrap();
        assert_eq!(name, "solo");
        assert_eq!(scratch.batch(), &[x1]);
    }

    #[test]
    fn lying_counts_fail_before_allocating() {
        // batch count says 4096 instances but only a few bytes follow
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_u32(&mut payload, MAX_BATCH);
        put_u32(&mut payload, 0);
        let mut scratch = BatchScratch::default();
        assert!(matches!(
            decode_predict_request(Op::PredictBatch, &payload, &mut scratch),
            Err(FrameError::Truncated)
        ));
        assert_eq!(scratch.instances.capacity(), 0);
        // over-cap batch count is its own typed error
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_u32(&mut payload, MAX_BATCH + 1);
        assert!(matches!(
            decode_predict_request(Op::PredictBatch, &payload, &mut scratch),
            Err(FrameError::OverCap("batch size"))
        ));
        // nnz over cap
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_u32(&mut payload, MAX_FEATURES + 1);
        assert!(matches!(
            decode_predict_request(Op::Predict, &payload, &mut scratch),
            Err(FrameError::OverCap("features per instance"))
        ));
        // nnz claims more features than bytes present
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_u32(&mut payload, 1000);
        put_u32(&mut payload, 1);
        put_f32(&mut payload, 1.0);
        assert!(matches!(
            decode_predict_request(Op::Predict, &payload, &mut scratch),
            Err(FrameError::Truncated)
        ));
    }

    #[test]
    fn oversized_scratch_capacity_is_not_retained_across_frames() {
        // one max-size instance must not pin its buffer forever: the
        // next frame's reuse shrinks the slot back under the bound
        let big: Vec<SparseFeat> =
            (0..MAX_FEATURES).map(|i| (i, 1.0)).collect();
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_instance(&mut payload, &big).unwrap();
        let mut scratch = BatchScratch::default();
        decode_predict_request(Op::Predict, &payload, &mut scratch).unwrap();
        assert_eq!(scratch.batch()[0].len(), MAX_FEATURES as usize);

        let mut small = Vec::new();
        put_name(&mut small, "m");
        put_instance(&mut small, &[(0, 1.0)]).unwrap();
        decode_predict_request(Op::Predict, &small, &mut scratch).unwrap();
        assert_eq!(scratch.batch(), &[vec![(0u32, 1.0f32)]]);
        // shrink_to may leave a little allocator slack, but nothing
        // near the max-size instance that came before
        assert!(
            scratch.instances[0].capacity() <= 2 * RETAINED_FEATURES,
            "retained {} features of capacity",
            scratch.instances[0].capacity()
        );
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut payload = Vec::new();
        put_name(&mut payload, "m");
        put_instance(&mut payload, &[(0, 1.0)]).unwrap();
        payload.push(0);
        let mut scratch = BatchScratch::default();
        assert!(matches!(
            decode_predict_request(Op::Predict, &payload, &mut scratch),
            Err(FrameError::BadPayload(_))
        ));
    }

    #[test]
    fn predict_response_round_trips_bit_exactly() {
        let preds = vec![0.5, -0.0, f64::MIN_POSITIVE, 1e300];
        let mut payload = Vec::new();
        put_predict_response(&mut payload, &preds, 9, 250);
        let mut back = Vec::new();
        let (version, staleness) =
            decode_predict_response(&payload, &mut back).unwrap();
        assert_eq!(version, 9);
        assert_eq!(staleness, 250);
        assert_eq!(back.len(), preds.len());
        for (a, b) in preds.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn stats_and_models_round_trip() {
        let s = StatsReport {
            bytes_in: 1,
            bytes_out: 2,
            frames_in: 3,
            frames_out: 4,
            decode_errors: 5,
            connections: 6,
            active_connections: 1,
            uptime_us: 99,
            registry_version: 11,
            registry_models: 1,
            models: vec![ModelStatsReport {
                name: "tree".into(),
                requests: 10,
                predictions: 20,
                p50_ns: 100,
                p99_ns: 900,
                max_ns: 1000,
                max_staleness: 7,
            }],
        };
        let mut payload = Vec::new();
        put_stats(&mut payload, &s);
        assert_eq!(decode_stats(&payload).unwrap(), s);

        let models = vec![ModelEntry {
            name: "sgd".into(),
            dim: 1024,
            params: 1024,
            snapshot_version: 3,
            trained_instances: 50_000,
        }];
        let mut payload = Vec::new();
        put_models(&mut payload, &models);
        assert_eq!(decode_models(&payload).unwrap(), models);
    }

    #[test]
    fn unrepresentable_names_are_omitted_not_desynced() {
        // a registry name longer than the one-byte length prefix can
        // never be addressed over the wire; the admin encoders must
        // skip it rather than wrap the length into a corrupt frame
        let models = vec![
            ModelEntry {
                name: "ok".into(),
                dim: 8,
                params: 8,
                snapshot_version: 0,
                trained_instances: 0,
            },
            ModelEntry {
                name: "x".repeat(MAX_NAME + 1),
                dim: 8,
                params: 8,
                snapshot_version: 0,
                trained_instances: 0,
            },
        ];
        let mut payload = Vec::new();
        put_models(&mut payload, &models);
        let back = decode_models(&payload).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].name, "ok");

        let s = StatsReport {
            models: vec![ModelStatsReport {
                name: "y".repeat(MAX_NAME + 1),
                requests: 1,
                predictions: 1,
                p50_ns: 0,
                p99_ns: 0,
                max_ns: 0,
                max_staleness: 0,
            }],
            ..Default::default()
        };
        let mut payload = Vec::new();
        put_stats(&mut payload, &s);
        assert!(decode_stats(&payload).unwrap().models.is_empty());
    }

    #[test]
    fn op_codes_round_trip() {
        for op in [
            Op::Predict,
            Op::PredictBatch,
            Op::Stats,
            Op::ListModels,
            Op::Ping,
            Op::Shutdown,
            Op::MetricsDump,
            Op::MetricsHistory,
        ] {
            assert_eq!(Op::from_u8(op as u8), Some(op));
        }
        assert_eq!(Op::from_u8(0), None);
        assert_eq!(Op::from_u8(200), None);
    }

    fn hist_snap(
        tick: u64,
        uptime_ms: u64,
        series: &[(&str, u64)],
    ) -> crate::obs::SeriesSnapshot {
        crate::obs::SeriesSnapshot {
            tick,
            uptime_ms,
            series: series
                .iter()
                .map(|&(n, v)| (n.to_string(), v))
                .collect(),
        }
    }

    #[test]
    fn history_payload_round_trips() {
        let snaps = vec![
            hist_snap(3, 1_000, &[("a_total", 5), ("b{l=\"x\"}", 1)]),
            hist_snap(4, 2_000, &[("a_total", 9)]),
            hist_snap(5, 3_000, &[]),
        ];
        let mut payload = Vec::new();
        put_history(&mut payload, &snaps);
        assert_eq!(decode_history(&payload).unwrap(), snaps);
        // empty history is well-formed
        let mut payload = Vec::new();
        put_history(&mut payload, &[]);
        assert!(decode_history(&payload).unwrap().is_empty());
    }

    #[test]
    fn history_encode_keeps_newest_under_caps() {
        // more snapshots than the cap: the oldest fall off
        let many: Vec<_> = (0..2 * MAX_HISTORY_SNAPSHOTS as u64)
            .map(|i| hist_snap(i, i * 10, &[("a", i)]))
            .collect();
        let mut payload = Vec::new();
        put_history(&mut payload, &many);
        let back = decode_history(&payload).unwrap();
        assert_eq!(back.len(), MAX_HISTORY_SNAPSHOTS as usize);
        assert_eq!(
            back.first().unwrap().tick,
            MAX_HISTORY_SNAPSHOTS as u64
        );
        assert_eq!(
            back.last().unwrap().tick,
            2 * MAX_HISTORY_SNAPSHOTS as u64 - 1
        );
        // an oversized name truncates but the payload still decodes
        let long = "n".repeat(2 * MAX_SERIES_NAME as usize);
        let snaps = vec![hist_snap(0, 0, &[(long.as_str(), 7)])];
        let mut payload = Vec::new();
        put_history(&mut payload, &snaps);
        let back = decode_history(&payload).unwrap();
        assert_eq!(
            back[0].series[0].0.len(),
            MAX_SERIES_NAME as usize
        );
        assert_eq!(back[0].series[0].1, 7);
    }

    #[test]
    fn history_truncation_at_every_boundary_errors_cleanly() {
        let snaps = vec![
            hist_snap(1, 500, &[("a_total", 5), ("b_total", 6)]),
            hist_snap(2, 900, &[("a_total", 8)]),
        ];
        let mut payload = Vec::new();
        put_history(&mut payload, &snaps);
        for cut in 0..payload.len() {
            assert!(
                decode_history(&payload[..cut]).is_err(),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn history_hostile_counts_rejected_before_allocation() {
        // snapshot count over cap
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::OverCap("history snapshot count"))
        ));
        // plausible snapshot count, no bytes behind it
        let mut payload = Vec::new();
        put_u32(&mut payload, 64);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::Truncated)
        ));
        // series count over cap inside an otherwise valid snapshot
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 0);
        put_u32(&mut payload, u32::MAX);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::OverCap("history series count"))
        ));
        // lying series count
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 0);
        put_u32(&mut payload, 1_024);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::Truncated)
        ));
        // name length over cap
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_u16(&mut payload, u16::MAX);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::OverCap("history series name"))
        ));
        // non-UTF-8 name
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_u16(&mut payload, 2);
        payload.extend_from_slice(&[0xFF, 0xFE]);
        put_u64(&mut payload, 0);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::BadPayload(_))
        ));
        // trailing bytes after a valid payload
        let mut payload = Vec::new();
        put_history(&mut payload, &[hist_snap(0, 0, &[])]);
        payload.push(0);
        assert!(matches!(
            decode_history(&payload),
            Err(FrameError::BadPayload(_))
        ));
    }

    #[test]
    fn incremental_decode_agrees_with_blocking_decode_byte_by_byte() {
        // feed the buffer one byte at a time: every prefix short of the
        // full frame is "incomplete", the full frame decodes to the
        // same fields read_frame produces, and trailing bytes from a
        // pipelined successor are left untouched
        let bytes = round_trip(Op::Ping as u8, STATUS_OK, 42, b"hello");
        for cut in 0..bytes.len() {
            match decode_frame_from(&bytes[..cut]) {
                Ok(None) => {}
                other => panic!("prefix {cut} should be incomplete: {other:?}"),
            }
        }
        let (f, consumed) =
            decode_frame_from(&bytes).expect("decode").expect("complete");
        assert_eq!(consumed, bytes.len());
        assert_eq!(f.op, Op::Ping as u8);
        assert_eq!(f.status, STATUS_OK);
        assert_eq!(f.req_id, 42);
        assert_eq!(f.payload, b"hello");
        assert_eq!(f.wire_bytes, bytes.len());
        // two pipelined frames: the first decodes, consumed points at
        // the second, which then decodes from the remainder
        let mut two = bytes.clone();
        let second = round_trip(Op::Ping as u8, STATUS_OK, 43, b"again");
        two.extend_from_slice(&second);
        let (f, consumed) =
            decode_frame_from(&two).expect("decode").expect("first");
        assert_eq!(f.req_id, 42);
        let (f2, c2) =
            decode_frame_from(&two[consumed..]).expect("decode").expect("second");
        assert_eq!(f2.req_id, 43);
        assert_eq!(consumed + c2, two.len());
    }

    #[test]
    fn incremental_decode_rejects_hostile_prefixes_before_buffering() {
        // a 4 GiB length claim fails with exactly four bytes on hand
        let claim = u32::MAX.to_le_bytes();
        assert!(matches!(
            decode_frame_from(&claim),
            Err(FrameError::BadLength { len: u32::MAX })
        ));
        // under-length claims too
        let tiny = 8u32.to_le_bytes();
        assert!(matches!(
            decode_frame_from(&tiny),
            Err(FrameError::BadLength { len: 8 })
        ));
        // three bytes of a hostile claim are still just "incomplete"
        assert!(matches!(decode_frame_from(&claim[..3]), Ok(None)));
        // corruption inside a complete frame is caught the same as the
        // blocking decoder
        let mut corrupt = round_trip(Op::Stats as u8, STATUS_OK, 7, b"");
        let n = corrupt.len();
        corrupt[n - 9] ^= 0xFF;
        assert!(matches!(
            decode_frame_from(&corrupt),
            Err(FrameError::ChecksumMismatch)
        ));
    }

    #[test]
    fn writer_enforces_reader_caps() {
        let mut w = FrameWriter::new();
        w.start(Op::Ping as u8, STATUS_OK, 1);
        w.payload().resize(MAX_FRAME as usize, 0);
        let mut out = Vec::new();
        assert!(w.finish_to(&mut out).is_err());
        assert!(out.is_empty(), "nothing written on refusal");
    }
}
