//! Regret against the best fixed linear predictor (eq. 0.1):
//!
//! Reg[W] = Σ_t [ℓ(ŷ_t, y_t) − ℓ(ŷ*_t, y_t)] where ŷ*_t = ⟨x_t, w*⟩ and
//! w* = argmin Σ ℓ(⟨w, x_t⟩, y_t), computed in hindsight.
//!
//! For squared loss, w* = Σ⁻¹b via the normal equations
//! ([`crate::linalg::LeastSquares`]); this powers the Theorem-1
//! delay-regret experiments (`benches/delay_regret.rs`), which check the
//! *growth shape* O(√(τT)) rather than the constant.

use crate::data::Dataset;
use crate::linalg::LeastSquares;
use crate::loss::Loss;

/// Hindsight-optimal squared-loss predictor over a dataset with a small
/// dense feature space (dim = `ds.dim` must be modest: the solver is
/// O(dim³)).
pub fn best_fixed_weights(ds: &Dataset, ridge: f64) -> Vec<f64> {
    let mut ls = LeastSquares::new(ds.dim);
    for inst in ds.iter() {
        ls.observe_sparse(&inst.features, inst.label);
    }
    ls.solve(ridge).unwrap_or_else(|| vec![0.0; ds.dim])
}

/// Cumulative regret of a recorded prediction sequence against w*.
pub fn regret(
    ds: &Dataset,
    predictions: &[f64],
    loss: Loss,
    w_star: &[f64],
) -> f64 {
    assert_eq!(predictions.len(), ds.len());
    let mut reg = 0.0;
    for (inst, &yhat) in ds.iter().zip(predictions) {
        let ystar: f64 = inst
            .features
            .iter()
            .map(|&(i, v)| w_star[i as usize] * v as f64)
            .sum();
        reg += loss.value(yhat, inst.label) - loss.value(ystar, inst.label);
    }
    reg
}

/// Run a learner closure over the dataset recording pre-update
/// predictions, then compute its regret. The closure receives
/// (features, label) and returns the pre-update prediction.
pub fn run_and_regret(
    ds: &Dataset,
    loss: Loss,
    ridge: f64,
    mut step: impl FnMut(&[(u32, f32)], f64) -> f64,
) -> (f64, Vec<f64>) {
    let preds: Vec<f64> =
        ds.iter().map(|inst| step(&inst.features, inst.label)).collect();
    let w_star = best_fixed_weights(ds, ridge);
    (regret(ds, &preds, loss, &w_star), preds)
}

/// Convenience: regret of plain SGD (Algorithm 1).
pub fn sgd_regret(
    ds: &Dataset,
    loss: Loss,
    lr: crate::lr::LrSchedule,
) -> f64 {
    let mut sgd = crate::learner::sgd::Sgd::new(ds.dim, loss, lr);
    let (reg, _) = run_and_regret(ds, loss, 1e-9, |x, y| {
        let yhat = sgd.predict(x);
        sgd.learn(x, y);
        yhat
    });
    reg
}

/// Convenience: regret of delayed SGD (Algorithm 2) with delay τ.
pub fn delayed_regret(
    ds: &Dataset,
    loss: Loss,
    lr: crate::lr::LrSchedule,
    tau: usize,
) -> f64 {
    let mut d = crate::learner::delayed::DelayedSgd::new(ds.dim, loss, lr, tau);
    let (reg, _) = run_and_regret(ds, loss, 1e-9, |x, y| d.round(x, y));
    reg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::instance::Instance;
    use crate::lr::LrSchedule;
    use crate::rng::Rng;

    /// Dense low-dim dataset where w* is exactly recoverable.
    fn dense_ds(n: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let w_true: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut ds = Dataset::new("dense", dim);
        for t in 0..n {
            let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            let y: f64 =
                x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    + 0.1 * rng.normal();
            ds.instances.push(Instance {
                label: y,
                weight: 1.0,
                features: x
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| (i as u32, v as f32))
                    .collect(),
                tag: t as u64,
            });
        }
        ds
    }

    #[test]
    fn best_fixed_recovers_planted() {
        let ds = dense_ds(2000, 4, 1);
        let w = best_fixed_weights(&ds, 1e-9);
        // regret of the best-fixed predictor against itself is zero
        let preds: Vec<f64> = ds
            .iter()
            .map(|i| {
                i.features
                    .iter()
                    .map(|&(j, v)| w[j as usize] * v as f64)
                    .sum()
            })
            .collect();
        let r = regret(&ds, &preds, Loss::Squared, &w);
        assert!(r.abs() < 1e-6, "r {r}");
    }

    #[test]
    fn sgd_regret_sublinear() {
        // Reg(T)/T must shrink as T grows (O(√T) for bounded gradients)
        let short = dense_ds(500, 4, 2);
        let long = dense_ds(5_000, 4, 2);
        let lr = LrSchedule::inv_sqrt(0.1, 10.0);
        let r_short = sgd_regret(&short, Loss::Squared, lr) / 500.0;
        let r_long = sgd_regret(&long, Loss::Squared, lr) / 5_000.0;
        assert!(r_long < r_short, "short {r_short} long {r_long}");
    }

    #[test]
    fn delay_increases_regret_on_adversarial() {
        use crate::data::synth::{AdversarialDupGen, SynthConfig};
        let cfg = SynthConfig {
            instances: 4_000,
            features: 64,
            density: 8,
            hash_bits: 8,
            noise: 0.0,
            seed: 3,
        };
        let ds = AdversarialDupGen::new(cfg, 16).generate();
        let lr = LrSchedule::inv_sqrt(0.25, 10.0);
        let r0 = delayed_regret(&ds, Loss::Squared, lr, 0);
        let r16 = delayed_regret(&ds, Loss::Squared, lr, 16);
        assert!(r16 > r0, "r0 {r0} r16 {r16}");
    }
}
