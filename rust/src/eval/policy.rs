//! Offline policy evaluator (Langford, Li & Strehl 2008 — "Exploration
//! Scavenging"), as used by the paper's ad-display experiments:
//! "element-wise evaluation with an offline policy evaluator".
//!
//! Given a log of display events where the logging policy chose
//! uniformly at random between two candidates, the value of a new policy
//! π (here: "show the ad the model scores higher") is estimated by
//! importance weighting: count a logged click only when π agrees with
//! the logged choice, scaled by 1/P(logged choice) = 2.

use crate::data::synth::ad_display::DisplayEvent;
use crate::linalg::SparseFeat;

/// Result of an offline evaluation.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyValue {
    /// Importance-weighted click-through estimate of the new policy.
    pub estimated_ctr: f64,
    /// CTR of the logging policy on the same log (baseline).
    pub logging_ctr: f64,
    /// Matched events (where π agreed with the log) — the effective
    /// sample size of the estimate.
    pub matched: usize,
    /// Events considered.
    pub total: usize,
    /// Ground-truth expected CTR of the new policy (computable only for
    /// synthetic data; the paper could not report this).
    pub true_ctr: f64,
}

/// Evaluate a scoring function `score(features) -> f64` (higher = show).
pub fn evaluate(
    score: impl Fn(&[SparseFeat]) -> f64,
    events: &[DisplayEvent],
) -> PolicyValue {
    let mut matched = 0usize;
    let mut weighted_clicks = 0.0;
    let mut log_clicks = 0u64;
    let mut true_sum = 0.0;
    for e in events {
        let pick = if score(&e.ad_a) >= score(&e.ad_b) { 0u8 } else { 1u8 };
        true_sum += if pick == 0 { e.ctr_a } else { e.ctr_b };
        if e.clicked {
            log_clicks += 1;
        }
        if pick == e.shown {
            matched += 1;
            if e.clicked {
                // logging policy is uniform over 2 arms: weight = 2
                weighted_clicks += 2.0;
            }
        }
    }
    let n = events.len().max(1) as f64;
    PolicyValue {
        estimated_ctr: weighted_clicks / n,
        logging_ctr: log_clicks as f64 / n,
        matched,
        total: events.len(),
        true_ctr: true_sum / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::ad_display::{AdDisplayConfig, AdDisplayGen};

    fn corpus() -> crate::data::synth::ad_display::AdDisplayCorpus {
        AdDisplayGen::new(AdDisplayConfig { events: 30_000, ..Default::default() })
            .generate()
    }

    #[test]
    fn random_policy_estimates_logging_ctr() {
        let c = corpus();
        // a constant-score policy ~ always picks ad A; estimator must be
        // unbiased for that policy's true value
        let v = evaluate(|_| 0.0, &c.events);
        assert!((v.estimated_ctr - v.true_ctr).abs() < 0.02,
            "est {} true {}", v.estimated_ctr, v.true_ctr);
    }

    #[test]
    fn oracle_policy_beats_logging() {
        let c = corpus();
        // oracle: score by true CTR (cheating — upper bound); identify
        // each candidate by its buffer address
        let events = &c.events;
        let mut by_ptr = std::collections::HashMap::new();
        for e in events {
            by_ptr.insert(e.ad_a.as_ptr() as usize, e.ctr_a);
            by_ptr.insert(e.ad_b.as_ptr() as usize, e.ctr_b);
        }
        let v = evaluate(|f| by_ptr[&(f.as_ptr() as usize)], events);
        assert!(v.estimated_ctr > v.logging_ctr * 1.1,
            "oracle {} logging {}", v.estimated_ctr, v.logging_ctr);
        assert!(v.true_ctr > v.logging_ctr);
    }

    #[test]
    fn matched_fraction_near_half_for_uniform() {
        let c = corpus();
        let v = evaluate(|f| f.len() as f64, &c.events);
        let frac = v.matched as f64 / v.total as f64;
        assert!((frac - 0.5).abs() < 0.02, "frac {frac}");
    }
}
