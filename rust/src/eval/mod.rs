//! Evaluation: offline policy evaluation (the §0.5.3 ad task) and regret
//! against the batch least-squares optimum (the Theorem-1 experiments).

pub mod policy;
pub mod regret;
