//! Evaluation: offline policy evaluation (the §0.5.3 ad task) and regret
//! against the batch least-squares optimum (the Theorem-1 experiments).

/// Off-policy value estimation.
pub mod policy;
/// Regret accounting against a fixed comparator.
pub mod regret;
